"""Routed message fabric: framing edge cases, CRC32, multi-hop routing,
flow control, reassembly, and the sharded serving plane.

Runs on the 8 simulated host devices from ``conftest.py`` (the CI
multi-device job re-runs this file explicitly)."""
import zlib

import jax
import jax.numpy as jnp
import numpy as np
import pytest

from repro.fabric import (
    Fabric,
    FabricConfig,
    SEQ_MOD,
    crc32_words,
    frame_stream,
    unframe_stream,
    unpack_route,
)


@pytest.fixture(scope="module")
def fab():
    """Shared 8-rank 1D fabric (tiny frames force multi-frame messages)."""
    return Fabric(n_ranks=8, config=FabricConfig(frame_phits=2, credits=2))


@pytest.fixture
def boxes(fab):
    return [fab.mailbox(r) for r in range(fab.n_ranks)]


# ---------------------------------------------------------------------------
# wire format: CRC32 + route words
# ---------------------------------------------------------------------------


def test_crc32_matches_zlib(rng):
    for n in (0, 1, 7, 64, 300):
        words = rng.integers(0, 1 << 32, n, dtype=np.uint64).astype(np.uint32)
        assert int(crc32_words(jnp.asarray(words))) == zlib.crc32(words.tobytes())


def test_crc32_catches_byte_reorder():
    """The seed's additive checksum was blind to reorders; CRC32 is not."""
    payload = jnp.arange(64, dtype=jnp.uint32)
    frames, _ = frame_stream(payload, jnp.asarray(256), frame_phits=4)
    swapped = frames.at[0, 4].set(frames[0, 5]).at[0, 5].set(frames[0, 4])
    assert not bool(unframe_stream(swapped)[2])
    flipped = frames.at[0, 8].add(1)
    assert not bool(unframe_stream(flipped)[2])


def test_route_words_and_seq():
    payload = jnp.arange(64, dtype=jnp.uint32)
    frames, nf = frame_stream(
        payload, jnp.asarray(256), frame_phits=2, route=(3, 6, SEQ_MOD - 2)
    )
    src, dst, seq = unpack_route(frames[:, 3])
    assert np.all(np.asarray(src) == 3) and np.all(np.asarray(dst) == 6)
    # seq increments per frame and wraps at 2**16 (terminator included)
    expect = [(SEQ_MOD - 2 + i) % SEQ_MOD for i in range(int(nf))]
    assert list(np.asarray(seq[: int(nf)])) == expect


# ---------------------------------------------------------------------------
# routed delivery
# ---------------------------------------------------------------------------


def test_all_to_all_1d(fab, boxes, rng):
    msgs = {}
    for s in range(8):
        for d in range(8):
            w = rng.integers(0, 256, int(rng.integers(1, 64)),
                             dtype=np.uint8).tobytes()
            msgs[(s, d)] = w
            boxes[s].send(d, w)
    fab.exchange()
    for d in range(8):
        got = boxes[d].recv()
        assert len(got) == 8
        for dl in got:
            assert dl.ok and dl.wire == msgs[(dl.src, d)]


@pytest.mark.parametrize("routing", ["dimension", "shortest"])
def test_all_to_all_2d_both_routing_modes(rng, routing):
    mesh = jax.make_mesh((4, 2), ("fx", "fy"))
    fab2 = Fabric(mesh=mesh, config=FabricConfig(
        frame_phits=2, credits=1, routing=routing))
    boxes = [fab2.mailbox(r) for r in range(8)]
    msgs = {}
    for s in range(8):
        for d in range(8):
            w = rng.integers(0, 256, int(rng.integers(1, 48)),
                             dtype=np.uint8).tobytes()
            msgs[(s, d)] = w
            boxes[s].send(d, w)
    fab2.exchange()
    for d in range(8):
        got = boxes[d].recv()
        assert len(got) == 8
        for dl in got:
            assert dl.ok and dl.wire == msgs[(dl.src, d)]
    # x-major rank layout: 0 -> 7 crosses 3 x-hops + 1 y-hop on the +1
    # ring, but only 1 x-hop (the -1 way) + 1 y-hop under shortest-path
    assert fab2.router.hops(0, 7) == 4
    assert fab2.router.min_hops(0, 7) == 2
    assert fab2.router.route_hops(0, 7) == (2 if routing == "shortest" else 4)


def test_hops_is_pure_host_math(fab):
    """Satellite: ``Router.hops`` is called per request by
    ``place_requests`` and must not build device arrays or force a sync —
    it returns plain python ints now."""
    r = fab.router
    assert isinstance(r.hops(0, 7), int)
    assert isinstance(r.min_hops(0, 7), int)
    assert r.hops(0, 7) == 7 and r.hops(7, 0) == 1  # +1 ring is directed
    assert r.min_hops(0, 7) == 1 and r.min_hops(7, 0) == 1  # shortest is not
    assert r.min_hops(0, 4) == 4  # antipode: both ways equal
    for s in range(8):
        for d in range(8):
            assert r.min_hops(s, d) == min(r.hops(s, d), r.hops(d, s))
            assert r.min_hops(s, d) <= r.hops(s, d)


def test_adaptive_bit_in_route_word():
    """Shortest-path frames carry the route-word adaptive bit; src/dst/seq
    survive it, and dimension-order frames stay bit-for-bit PR-3."""
    from repro.fabric import frame_stream as fs, route_adaptive

    payload = jnp.arange(16, dtype=jnp.uint32)
    fr_sp, _ = fs(payload, jnp.asarray(64), frame_phits=2, route=(3, 6, 0),
                  adaptive=True)
    fr_dim, _ = fs(payload, jnp.asarray(64), frame_phits=2, route=(3, 6, 0))
    assert bool(np.all(np.asarray(route_adaptive(fr_sp))))
    assert not bool(np.any(np.asarray(route_adaptive(fr_dim))))
    for fr in (fr_sp, fr_dim):
        src, dst, seq = unpack_route(fr[:, 3])
        assert np.all(np.asarray(src) == 3) and np.all(np.asarray(dst) == 6)
    # only the route word (and therefore the CRC word) differ
    same = np.asarray(fr_sp) == np.asarray(fr_dim)
    assert same[:, [0, 1]].all() and same[:, 4:].all()
    # both pass CRC: the adaptive bit is covered by the checksum
    from repro.fabric import verify_frames
    assert bool(np.all(np.asarray(verify_frames(fr_sp))))


def test_empty_frame_terminators_delimit_messages(fab, boxes):
    """Back-to-back tiny messages each arrive as their own delivery — one
    terminator frame per message (paper §IV-C rule)."""
    for _ in range(3):
        boxes[2].send(5, b"z")
    boxes[2].send(5, b"payload")
    fab.exchange()
    got = boxes[5].recv()
    assert [d.wire for d in got] == [b"z", b"z", b"z", b"payload"]
    assert all(d.ok and d.src == 2 for d in got)


def test_odd_length_payloads(fab, boxes):
    """Byte lengths that don't fill a u32 lane survive the fabric."""
    wires = [b"x", b"ab", b"abc", b"abcde" * 7]
    for w in wires:
        boxes[1].send(4, w)
    fab.exchange()
    assert [d.wire for d in boxes[4].recv()] == wires


def test_interleaved_sources_reassemble(fab, boxes, rng):
    """Many sources target one rank with multi-frame messages; frames
    interleave on the links and the seq words put them back together."""
    msgs = {}
    for s in range(8):
        if s == 3:
            continue
        msgs[s] = [
            rng.integers(0, 256, int(rng.integers(20, 90)),
                         dtype=np.uint8).tobytes()
            for _ in range(3)
        ]
        for w in msgs[s]:
            boxes[s].send(3, w)
    fab.exchange()
    got = boxes[3].recv()
    per_src = {}
    for dl in got:
        assert dl.ok
        per_src.setdefault(dl.src, []).append(dl.wire)
    assert {s: ws for s, ws in per_src.items()} == msgs  # FIFO per source


def test_seq_wrap_across_exchange(fab, boxes):
    """The u16 seq wraps mid-message; the wrap-aware receiver still orders
    the frames correctly."""
    fab._tx_seq[6][0] = SEQ_MOD - 3
    fab._rx_seq[0][6] = SEQ_MOD - 3
    w = bytes(range(200))  # many frames at frame_phits=2 -> wraps mid-stream
    boxes[6].send(0, w)
    fab.exchange()
    (dl,) = boxes[0].recv()
    assert dl.ok and dl.wire == w


def test_credit_flow_control_single_credit(rng):
    """credits=1 serializes every link to one frame per step; a burst still
    arrives complete, in order, and bit-exact."""
    fab1 = Fabric(n_ranks=8, config=FabricConfig(frame_phits=1, credits=1))
    a, b = fab1.mailbox(0), fab1.mailbox(5)
    wires = [
        rng.integers(0, 256, int(rng.integers(10, 60)), dtype=np.uint8).tobytes()
        for _ in range(6)
    ]
    for w in wires:
        a.send(5, w)
    fab1.exchange()
    assert [d.wire for d in b.recv()] == wires


def test_corrupted_frame_flagged_end_to_end(rng):
    """A bit flipped in transit flags exactly the message it belongs to."""
    fabc = Fabric(n_ranks=8, config=FabricConfig(frame_phits=2, credits=4))
    boxes = [fabc.mailbox(r) for r in range(8)]
    wires = {s: bytes([s] * 40) for s in range(3)}
    for s, w in wires.items():
        boxes[s].send(7, w)

    def corrupt(tx, tx_valid):
        tx = np.array(tx)
        tx[1, 0, 6] ^= 0x10  # payload word of a frame from src rank 1
        return tx

    fabc.tx_hook = corrupt
    fabc.exchange()
    got = {d.src: d for d in boxes[7].recv()}
    assert not fabc.last_crc_ok  # the router saw it on-device too
    assert not got[1].ok and got[1].wire != wires[1]
    assert got[0].ok and got[0].wire == wires[0]
    assert got[2].ok and got[2].wire == wires[2]


def test_corrupted_header_flagged_end_to_end(rng):
    """The CRC covers the header words too: a flipped SIZE bit (silent
    truncation), a flipped seq bit, and a flipped dst byte (misroute to a
    valid wrong rank, leaving a seq gap) are all detected."""
    from repro.fabric.frames import HDR_SIZE, HDR_ROUTE

    for word, flip in ((HDR_SIZE, 0x30), (HDR_ROUTE, 0x01),
                       (HDR_ROUTE, 1 << 16)):
        fabh = Fabric(n_ranks=8, config=FabricConfig(frame_phits=2, credits=4))
        boxes = [fabh.mailbox(r) for r in range(8)]
        boxes[1].send(4, bytes(range(64)))

        def corrupt(tx, tx_valid, word=word, flip=flip):
            tx = np.array(tx)
            tx[1, 0, word] ^= flip  # header word of the first frame
            return tx

        fabh.tx_hook = corrupt
        fabh.exchange()
        got = boxes[4].recv()
        # a route flip may strand or misdeliver the frame; whatever arrives
        # on the (1 -> 4) stream must be flagged, and nothing may come back
        # clean AND equal to the original bytes
        assert not fabh.last_crc_ok or not any(
            d.ok and d.wire == bytes(range(64)) for d in got
        )
        if word == HDR_SIZE:
            (dl,) = got
            assert not dl.ok  # truncated message is flagged, not silent


def test_bad_sends_rejected(fab):
    """send() validates its arguments up front with clear ValueErrors
    instead of failing deep inside the jitted router scan."""
    with pytest.raises(ValueError):
        fab.mailbox(0).send(8, b"x")  # dst outside the fabric
    with pytest.raises(ValueError):
        fab.send(-1, 0, b"x")  # src outside the fabric
    with pytest.raises(ValueError, match="empty wire"):
        fab.mailbox(0).send(1, b"")
    with pytest.raises(ValueError, match="bytes-like"):
        fab.mailbox(0).send(1, "not bytes")
    with pytest.raises(ValueError):
        fab.mailbox(9)


# ---------------------------------------------------------------------------
# nested ListLevel resync through the fabric
# ---------------------------------------------------------------------------


def test_nested_list_wire_survives_fragmentation():
    """A wire with nested Lists (request schema: List of prompts, each a
    List of tokens) is fragmented into 4-word frames, routed 3 hops, and
    the schema DES resyncs perfectly on the reassembled stream."""
    from repro.launch.serve import decode_request, encode_request

    fabn = Fabric(n_ranks=8, config=FabricConfig(frame_phits=1, credits=2))
    prompts = [[5, 6, 7], [], [9] * 17, [1]]
    wire = encode_request(42, prompts)
    fabn.mailbox(2).send(5, wire, list_level=2)
    fabn.exchange()
    (dl,) = fabn.mailbox(5).recv()
    assert dl.ok and dl.list_level == 2
    req_id, got = decode_request(dl.wire)
    assert req_id == 42 and got == prompts


# ---------------------------------------------------------------------------
# batched pack/unpack kernels
# ---------------------------------------------------------------------------


def test_pack_frames_batch_matches_frame_stream(rng):
    from repro.kernels import decode_frames_batch, encode_frames_batch

    B, cap_words, phits = 5, 24, 2
    payloads = rng.integers(0, 1 << 32, (B, cap_words),
                            dtype=np.uint64).astype(np.uint32)
    nbytes = np.asarray([0, 5, 40, 96, 64], np.int32)
    routes = np.stack([np.arange(B), (np.arange(B) + 1) % 8,
                       np.arange(B) * 10], axis=1).astype(np.int32)
    frames, n_frames = encode_frames_batch(
        jnp.asarray(payloads), jnp.asarray(nbytes), jnp.asarray(routes),
        frame_phits=phits,
    )
    for i in range(B):
        ref, nf = frame_stream(
            jnp.asarray(payloads[i]), jnp.asarray(nbytes[i]),
            frame_phits=phits,
            route=(routes[i, 0], routes[i, 1], routes[i, 2]),
        )
        np.testing.assert_array_equal(np.asarray(frames[i]), np.asarray(ref))
        assert int(n_frames[i]) == int(nf)
    # RX split kernel inverts the layout
    flat = frames.reshape(-1, frames.shape[-1])
    hdr, pay = decode_frames_batch(flat)
    np.testing.assert_array_equal(np.asarray(hdr), np.asarray(flat[:, :4]))
    np.testing.assert_array_equal(np.asarray(pay), np.asarray(flat[:, 4:]))


# ---------------------------------------------------------------------------
# fused single-jit tick vs the three-program path
# ---------------------------------------------------------------------------


def _exchange_and_drain(fab, sends):
    for s, d, w, lvl in sends:
        fab.mailbox(s).send(d, w, list_level=lvl)
    fab.exchange()
    return {
        r: [(dl.src, dl.wire, dl.ok, dl.list_level)
            for dl in fab.mailbox(r).recv()]
        for r in range(fab.n_ranks)
    }


@pytest.mark.parametrize("routing", ["dimension", "shortest"])
def test_fused_tick_identical_to_three_program_path(rng, routing):
    """Regression: the fused single-jit tick (pack -> routed scan -> RX
    split in one program) reassembles exactly the wires the PR-3
    three-program path does — mixed ListLevels, multi-frame messages, and
    multiple ticks (seq continuity) included."""
    cfg = dict(frame_phits=2, credits=2, routing=routing)
    fab_fused = Fabric(n_ranks=8, config=FabricConfig(fused=True, **cfg))
    fab_prog = Fabric(n_ranks=8, config=FabricConfig(fused=False, **cfg))
    for tick in range(2):
        sends = []
        for s in range(8):
            for _ in range(int(rng.integers(1, 3))):
                d = int(rng.integers(0, 8))
                w = rng.integers(0, 256, int(rng.integers(1, 80)),
                                 dtype=np.uint8).tobytes()
                sends.append((s, d, w, int(rng.integers(1, 4))))
        got_f = _exchange_and_drain(fab_fused, sends)
        got_p = _exchange_and_drain(fab_prog, sends)
        assert got_f == got_p, f"tick {tick}"


def test_tx_hook_falls_back_to_three_program_path():
    """Fault injection needs the framed TX on host, so setting ``tx_hook``
    must route the tick through the unfused path even when fused=True."""
    fab = Fabric(n_ranks=8, config=FabricConfig(frame_phits=2, fused=True))
    seen = []

    def hook(tx, tx_valid):
        seen.append(tx.shape)
        return tx

    fab.tx_hook = hook
    fab.mailbox(0).send(3, b"hooked")
    fab.exchange()
    assert seen  # the hook ran: three-program path was taken
    (dl,) = fab.mailbox(3).recv()
    assert dl.ok and dl.wire == b"hooked"


def test_tick_bucket_memoized_and_logged_once(caplog):
    """Satellite: a tick landing in a previously-seen shape bucket must not
    create a new jit entry, and a NEW bucket logs exactly once (steady-state
    serving never recompiles silently)."""
    import logging

    fab = Fabric(n_ranks=8, config=FabricConfig(frame_phits=2, credits=2))
    with caplog.at_level(logging.INFO, logger="repro.fabric.mailbox"):
        for tick in range(3):  # same traffic shape every tick
            for s in range(4):
                fab.mailbox(s).send((s + 2) % 8, bytes([tick, s]) * 16)
            fab.exchange()
    bucket_lines = [r for r in caplog.records if "bucket" in r.message]
    assert len(bucket_lines) == 1  # first tick compiles, the rest reuse
    assert len(fab.router._fused) == 1  # one jitted tick program
    n_buckets = len(fab._tick_buckets)
    with caplog.at_level(logging.INFO, logger="repro.fabric.mailbox"):
        caplog.clear()
        fab.mailbox(0).send(1, bytes(4096))  # much longer wire: new bucket
        fab.exchange()
    assert len(fab._tick_buckets) == n_buckets + 1
    assert sum("bucket" in r.message for r in caplog.records) == 1


# ---------------------------------------------------------------------------
# property test: routing modes deliver byte-identical message sets
# ---------------------------------------------------------------------------


#: the three routing disciplines that must deliver byte-identical message
#: sets: legacy +1-only, static per-frame shortest path, and shortest path
#: with congestion-aware direction defection
ROUTING_MODES = (
    dict(routing="dimension"),
    dict(routing="shortest"),
    dict(routing="shortest", defect_after=1),
)


def _seed_near_seq_wrap(fab):
    """Start every (src, dst) stream 3 frames before the u16 seq wrap so a
    multi-tick run crosses it."""
    for s in range(fab.n_ranks):
        for d in range(fab.n_ranks):
            fab._tx_seq[s][d] = SEQ_MOD - 3
            fab._rx_seq[d][s] = SEQ_MOD - 3


def test_routing_modes_deliver_identical_messages_property():
    """Satellite: under random sends, QoS credit classes, multiple ticks,
    and a u16 seq wrap, dimension-order, static shortest-path, and
    defection-enabled shortest-path routing must deliver byte-identical
    message sets — direction choices (static or congestion-driven) change
    hop paths and arrival interleavings, never wires, CRC verdicts, or
    per-(src, dst) order."""
    hypothesis = pytest.importorskip("hypothesis")
    from hypothesis import given, settings, strategies as st

    @st.composite
    def ticks(draw):
        out = []
        for _ in range(draw(st.integers(1, 2))):
            n_sends = draw(st.integers(1, 8))
            sends = []
            for _ in range(n_sends):
                s = draw(st.integers(0, 7))
                d = draw(st.integers(0, 7))
                nbytes = draw(st.integers(1, 64))
                lvl = draw(st.integers(1, 4))
                payload = bytes(
                    draw(st.lists(st.integers(0, 255), min_size=nbytes,
                                  max_size=nbytes))
                )
                sends.append((s, d, payload, lvl))
            out.append(sends)
        return out

    @settings(max_examples=8, deadline=None)
    @given(ticks())
    def check(tick_sends):
        got = {}
        for cfg in ROUTING_MODES:
            fab = Fabric(n_ranks=8, config=FabricConfig(
                frame_phits=1, credits=2, qos_weights=(2, 1), **cfg))
            _seed_near_seq_wrap(fab)  # every stream crosses the u16 wrap
            drained = []
            for sends in tick_sends:
                drained.append(_exchange_and_drain(fab, sends))
            got[tuple(cfg.items())] = drained
        # per-rank multisets of (src, wire, ok, level) must match; within
        # one (src, dst) stream even the order must match (FIFO per path)
        base_key, *others = got
        for other in others:
            for base_tick, other_tick in zip(got[base_key], got[other]):
                for r in range(8):
                    dim, alt = base_tick[r], other_tick[r]
                    assert sorted(dim) == sorted(alt)
                    for s in range(8):
                        assert [x for x in dim if x[0] == s] == \
                               [x for x in alt if x[0] == s]

    check()


def test_routing_modes_identical_under_single_credit(rng):
    """credits=1 maximally serializes every scheduler (and makes defection
    trivially reachable); the delivered bytes still cannot differ between
    the three routing modes, across two ticks that cross the seq wrap."""
    outs = []
    for cfg in ROUTING_MODES:
        fab = Fabric(n_ranks=8, config=FabricConfig(
            frame_phits=1, credits=1, **cfg))
        _seed_near_seq_wrap(fab)
        rng_ = np.random.default_rng(7)
        drained = []
        for _ in range(2):
            sends = []
            for s in range(8):
                d = int(rng_.integers(0, 8))
                w = rng_.integers(0, 256, int(rng_.integers(8, 40)),
                                  dtype=np.uint8).tobytes()
                sends.append((s, d, w, 1 + (s % 2)))
            drained.append(_exchange_and_drain(fab, sends))
        outs.append([
            {r: sorted(v) for r, v in tick.items()} for tick in drained
        ])
    assert outs[0] == outs[1] == outs[2]


def test_defection_escapes_starved_link():
    """One saturated +1 link: a heavy burst 0 -> 1 starves the light
    tenant's 0 -> 4 frames, which share the same outgoing link.  With
    ``defect_after`` set the light frames defect to the idle -1 ring after
    the starvation threshold, arriving strictly earlier — and the wires
    stay byte-identical in both modes."""
    wires = {}

    def run(defect_after):
        fab = Fabric(n_ranks=8, config=FabricConfig(
            frame_phits=2, credits=1, routing="shortest",
            defect_after=defect_after))
        for i in range(6):
            fab.send(0, 1, bytes([i]) * 200, list_level=2)
        for i in range(4):
            fab.send(0, 4, bytes([64 + i]) * 200, list_level=1)
        fab.exchange()
        light = fab.mailbox(4).recv()
        heavy = fab.mailbox(1).recv()
        assert all(d.ok for d in light + heavy)
        got = ([d.wire for d in heavy], [d.wire for d in light])
        wires.setdefault("ref", got)
        assert got == wires["ref"]  # defection never changes bytes
        return max(d.arrive_step for d in light)

    static_last = run(0)
    defect_last = run(2)
    assert defect_last < static_last  # escaped the starved link


def test_defection_idle_fabric_matches_static_paths():
    """With no congestion nothing ever starves, so defection must leave
    arrival steps exactly at the static shortest-path values."""
    steps = {}
    for k in (0, 2):
        fab = Fabric(n_ranks=8, config=FabricConfig(
            frame_phits=2, credits=4, routing="shortest", defect_after=k))
        for d in range(1, 8):
            fab.send(0, d, bytes([d]) * 32)
        fab.exchange()
        steps[k] = {
            d: fab.mailbox(d).recv()[0].arrive_step for d in range(1, 8)
        }
    assert steps[0] == steps[2]


def test_early_exit_matches_bounded_scan(rng):
    """The early-exit while_loop must deliver exactly what the full
    static-bound scan delivers (same bytes, same arrival steps)."""
    sends = []
    for s in range(8):
        for _ in range(2):
            d = int(rng.integers(0, 8))
            w = rng.integers(0, 256, int(rng.integers(1, 80)),
                             dtype=np.uint8).tobytes()
            sends.append((s, d, w, int(rng.integers(1, 4))))
    outs = []
    for early in (True, False):
        fab = Fabric(n_ranks=8, config=FabricConfig(
            frame_phits=2, credits=2, early_exit=early))
        for s_, d_, w_, lvl in sends:
            fab.send(s_, d_, w_, list_level=lvl)
        fab.exchange()
        outs.append({
            r: [(dl.src, dl.wire, dl.ok, dl.list_level, dl.arrive_step)
                for dl in fab.mailbox(r).recv()]
            for r in range(8)
        })
    assert outs[0] == outs[1]


def test_defect_after_config_validation():
    with pytest.raises(ValueError, match="defect_after"):
        FabricConfig(defect_after=-1)
    with pytest.raises(ValueError, match="shortest"):
        FabricConfig(routing="dimension", defect_after=2)
    assert FabricConfig(defect_after=3).defection
    assert not FabricConfig().defection


def test_max_ranks_enforced_at_construction():
    """Satellite: since the route word's src field shrank to u7 (PR 4),
    fabrics beyond MAX_RANKS=128 must be rejected with a clear error at
    construction instead of silently aliasing ranks mod 128."""
    from types import SimpleNamespace

    from repro.fabric import MAX_RANKS
    from repro.fabric.router import Router

    assert MAX_RANKS == 128
    # Fabric(n_ranks=...) fails BEFORE trying to allocate devices
    with pytest.raises(ValueError, match="MAX_RANKS"):
        Fabric(n_ranks=MAX_RANKS + 1)
    # Router checks any mesh handed to it directly; __init__ only reads
    # the shape, so a stub mesh exercises the boundary without 129 devices
    def stub(n):
        return SimpleNamespace(axis_names=("fx",), shape={"fx": n})

    with pytest.raises(ValueError, match="MAX_RANKS"):
        Router(stub(MAX_RANKS + 1))
    r = Router(stub(MAX_RANKS))  # the boundary itself is legal
    assert r.n_ranks == MAX_RANKS
    assert r.hops(0, MAX_RANKS - 1) == MAX_RANKS - 1


def test_list_level_validated(fab):
    """Satellite: out-of-range ListLevels would wrap through the u8 header
    budget and alias another tenant's QoS class (the router keys credit
    classes on level % n_classes) — reject them at send() with a clear
    error, like the existing rank/bytes checks."""
    box = fab.mailbox(0)
    for bad in (-1, 256, 1000, 1.5, "2", None):
        with pytest.raises(ValueError, match="list_level"):
            box.send(1, b"payload", list_level=bad)
    n_pending = len(fab._pending)
    box.send(1, b"ok-min", list_level=0)  # boundary values are legal
    box.send(1, b"ok-max", list_level=255)
    assert len(fab._pending) == n_pending + 2
    fab._pending = fab._pending[:n_pending]  # don't leak into other tests


# ---------------------------------------------------------------------------
# sharded serving over the fabric
# ---------------------------------------------------------------------------


def test_sharded_serving_token_identical():
    import dataclasses

    from repro.configs import get_config, smoke_config
    from repro.launch.serve import (
        decode_response, encode_request, serve_requests,
        serve_requests_sharded,
    )
    from repro.models import init_params

    cfg = dataclasses.replace(smoke_config(get_config("yi-6b")), n_layers=2)
    params = init_params(cfg, jax.random.PRNGKey(0))
    rng = np.random.default_rng(0)
    wires = []
    for r in range(5):
        prompts = [
            list(map(int, rng.integers(2, cfg.vocab, int(rng.integers(8, 16)))))
            for _ in range(int(rng.integers(1, 3)))
        ]
        wires.append(encode_request(r, prompts))
    batched = serve_requests(params, cfg, wires, max_new=4, pad_to=8, slots=4)
    sharded = serve_requests_sharded(
        params, cfg, wires, max_new=4, pad_to=8, slots=4, n_shards=3
    )
    assert sharded == batched  # byte-identical response wires
    for w in sharded:
        rid, outs = decode_response(w)
        assert all(len(o) == 4 for o in outs)
