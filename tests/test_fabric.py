"""Routed message fabric: framing edge cases, CRC32, multi-hop routing,
flow control, reassembly, and the sharded serving plane.

Runs on the 8 simulated host devices from ``conftest.py`` (the CI
multi-device job re-runs this file explicitly)."""
import zlib

import jax
import jax.numpy as jnp
import numpy as np
import pytest

from repro.fabric import (
    Fabric,
    FabricConfig,
    SEQ_MOD,
    crc32_words,
    frame_stream,
    unframe_stream,
    unpack_route,
)


@pytest.fixture(scope="module")
def fab():
    """Shared 8-rank 1D fabric (tiny frames force multi-frame messages)."""
    return Fabric(n_ranks=8, config=FabricConfig(frame_phits=2, credits=2))


@pytest.fixture
def boxes(fab):
    return [fab.mailbox(r) for r in range(fab.n_ranks)]


# ---------------------------------------------------------------------------
# wire format: CRC32 + route words
# ---------------------------------------------------------------------------


def test_crc32_matches_zlib(rng):
    for n in (0, 1, 7, 64, 300):
        words = rng.integers(0, 1 << 32, n, dtype=np.uint64).astype(np.uint32)
        assert int(crc32_words(jnp.asarray(words))) == zlib.crc32(words.tobytes())


def test_crc32_catches_byte_reorder():
    """The seed's additive checksum was blind to reorders; CRC32 is not."""
    payload = jnp.arange(64, dtype=jnp.uint32)
    frames, _ = frame_stream(payload, jnp.asarray(256), frame_phits=4)
    swapped = frames.at[0, 4].set(frames[0, 5]).at[0, 5].set(frames[0, 4])
    assert not bool(unframe_stream(swapped)[2])
    flipped = frames.at[0, 8].add(1)
    assert not bool(unframe_stream(flipped)[2])


def test_route_words_and_seq():
    payload = jnp.arange(64, dtype=jnp.uint32)
    frames, nf = frame_stream(
        payload, jnp.asarray(256), frame_phits=2, route=(3, 6, SEQ_MOD - 2)
    )
    src, dst, seq = unpack_route(frames[:, 3])
    assert np.all(np.asarray(src) == 3) and np.all(np.asarray(dst) == 6)
    # seq increments per frame and wraps at 2**16 (terminator included)
    expect = [(SEQ_MOD - 2 + i) % SEQ_MOD for i in range(int(nf))]
    assert list(np.asarray(seq[: int(nf)])) == expect


# ---------------------------------------------------------------------------
# routed delivery
# ---------------------------------------------------------------------------


def test_all_to_all_1d(fab, boxes, rng):
    msgs = {}
    for s in range(8):
        for d in range(8):
            w = rng.integers(0, 256, int(rng.integers(1, 64)),
                             dtype=np.uint8).tobytes()
            msgs[(s, d)] = w
            boxes[s].send(d, w)
    fab.exchange()
    for d in range(8):
        got = boxes[d].recv()
        assert len(got) == 8
        for dl in got:
            assert dl.ok and dl.wire == msgs[(dl.src, d)]


def test_all_to_all_2d_dimension_ordered(rng):
    mesh = jax.make_mesh((4, 2), ("fx", "fy"))
    fab2 = Fabric(mesh=mesh, config=FabricConfig(frame_phits=2, credits=1))
    boxes = [fab2.mailbox(r) for r in range(8)]
    msgs = {}
    for s in range(8):
        for d in range(8):
            w = rng.integers(0, 256, int(rng.integers(1, 48)),
                             dtype=np.uint8).tobytes()
            msgs[(s, d)] = w
            boxes[s].send(d, w)
    fab2.exchange()
    for d in range(8):
        got = boxes[d].recv()
        assert len(got) == 8
        for dl in got:
            assert dl.ok and dl.wire == msgs[(dl.src, d)]
    # x-major rank layout: 0 -> 7 crosses 3 x-hops + 1 y-hop
    assert fab2.router.hops(0, 7) == 4


def test_empty_frame_terminators_delimit_messages(fab, boxes):
    """Back-to-back tiny messages each arrive as their own delivery — one
    terminator frame per message (paper §IV-C rule)."""
    for _ in range(3):
        boxes[2].send(5, b"z")
    boxes[2].send(5, b"payload")
    fab.exchange()
    got = boxes[5].recv()
    assert [d.wire for d in got] == [b"z", b"z", b"z", b"payload"]
    assert all(d.ok and d.src == 2 for d in got)


def test_odd_length_payloads(fab, boxes):
    """Byte lengths that don't fill a u32 lane survive the fabric."""
    wires = [b"x", b"ab", b"abc", b"abcde" * 7]
    for w in wires:
        boxes[1].send(4, w)
    fab.exchange()
    assert [d.wire for d in boxes[4].recv()] == wires


def test_interleaved_sources_reassemble(fab, boxes, rng):
    """Many sources target one rank with multi-frame messages; frames
    interleave on the links and the seq words put them back together."""
    msgs = {}
    for s in range(8):
        if s == 3:
            continue
        msgs[s] = [
            rng.integers(0, 256, int(rng.integers(20, 90)),
                         dtype=np.uint8).tobytes()
            for _ in range(3)
        ]
        for w in msgs[s]:
            boxes[s].send(3, w)
    fab.exchange()
    got = boxes[3].recv()
    per_src = {}
    for dl in got:
        assert dl.ok
        per_src.setdefault(dl.src, []).append(dl.wire)
    assert {s: ws for s, ws in per_src.items()} == msgs  # FIFO per source


def test_seq_wrap_across_exchange(fab, boxes):
    """The u16 seq wraps mid-message; the wrap-aware receiver still orders
    the frames correctly."""
    fab._tx_seq[6][0] = SEQ_MOD - 3
    fab._rx_seq[0][6] = SEQ_MOD - 3
    w = bytes(range(200))  # many frames at frame_phits=2 -> wraps mid-stream
    boxes[6].send(0, w)
    fab.exchange()
    (dl,) = boxes[0].recv()
    assert dl.ok and dl.wire == w


def test_credit_flow_control_single_credit(rng):
    """credits=1 serializes every link to one frame per step; a burst still
    arrives complete, in order, and bit-exact."""
    fab1 = Fabric(n_ranks=8, config=FabricConfig(frame_phits=1, credits=1))
    a, b = fab1.mailbox(0), fab1.mailbox(5)
    wires = [
        rng.integers(0, 256, int(rng.integers(10, 60)), dtype=np.uint8).tobytes()
        for _ in range(6)
    ]
    for w in wires:
        a.send(5, w)
    fab1.exchange()
    assert [d.wire for d in b.recv()] == wires


def test_corrupted_frame_flagged_end_to_end(rng):
    """A bit flipped in transit flags exactly the message it belongs to."""
    fabc = Fabric(n_ranks=8, config=FabricConfig(frame_phits=2, credits=4))
    boxes = [fabc.mailbox(r) for r in range(8)]
    wires = {s: bytes([s] * 40) for s in range(3)}
    for s, w in wires.items():
        boxes[s].send(7, w)

    def corrupt(tx, tx_valid):
        tx = np.array(tx)
        tx[1, 0, 6] ^= 0x10  # payload word of a frame from src rank 1
        return tx

    fabc.tx_hook = corrupt
    fabc.exchange()
    got = {d.src: d for d in boxes[7].recv()}
    assert not fabc.last_crc_ok  # the router saw it on-device too
    assert not got[1].ok and got[1].wire != wires[1]
    assert got[0].ok and got[0].wire == wires[0]
    assert got[2].ok and got[2].wire == wires[2]


def test_corrupted_header_flagged_end_to_end(rng):
    """The CRC covers the header words too: a flipped SIZE bit (silent
    truncation), a flipped seq bit, and a flipped dst byte (misroute to a
    valid wrong rank, leaving a seq gap) are all detected."""
    from repro.fabric.frames import HDR_SIZE, HDR_ROUTE

    for word, flip in ((HDR_SIZE, 0x30), (HDR_ROUTE, 0x01),
                       (HDR_ROUTE, 1 << 16)):
        fabh = Fabric(n_ranks=8, config=FabricConfig(frame_phits=2, credits=4))
        boxes = [fabh.mailbox(r) for r in range(8)]
        boxes[1].send(4, bytes(range(64)))

        def corrupt(tx, tx_valid, word=word, flip=flip):
            tx = np.array(tx)
            tx[1, 0, word] ^= flip  # header word of the first frame
            return tx

        fabh.tx_hook = corrupt
        fabh.exchange()
        got = boxes[4].recv()
        # a route flip may strand or misdeliver the frame; whatever arrives
        # on the (1 -> 4) stream must be flagged, and nothing may come back
        # clean AND equal to the original bytes
        assert not fabh.last_crc_ok or not any(
            d.ok and d.wire == bytes(range(64)) for d in got
        )
        if word == HDR_SIZE:
            (dl,) = got
            assert not dl.ok  # truncated message is flagged, not silent


def test_bad_sends_rejected(fab):
    """send() validates its arguments up front with clear ValueErrors
    instead of failing deep inside the jitted router scan."""
    with pytest.raises(ValueError):
        fab.mailbox(0).send(8, b"x")  # dst outside the fabric
    with pytest.raises(ValueError):
        fab.send(-1, 0, b"x")  # src outside the fabric
    with pytest.raises(ValueError, match="empty wire"):
        fab.mailbox(0).send(1, b"")
    with pytest.raises(ValueError, match="bytes-like"):
        fab.mailbox(0).send(1, "not bytes")
    with pytest.raises(ValueError):
        fab.mailbox(9)


# ---------------------------------------------------------------------------
# nested ListLevel resync through the fabric
# ---------------------------------------------------------------------------


def test_nested_list_wire_survives_fragmentation():
    """A wire with nested Lists (request schema: List of prompts, each a
    List of tokens) is fragmented into 4-word frames, routed 3 hops, and
    the schema DES resyncs perfectly on the reassembled stream."""
    from repro.launch.serve import decode_request, encode_request

    fabn = Fabric(n_ranks=8, config=FabricConfig(frame_phits=1, credits=2))
    prompts = [[5, 6, 7], [], [9] * 17, [1]]
    wire = encode_request(42, prompts)
    fabn.mailbox(2).send(5, wire, list_level=2)
    fabn.exchange()
    (dl,) = fabn.mailbox(5).recv()
    assert dl.ok and dl.list_level == 2
    req_id, got = decode_request(dl.wire)
    assert req_id == 42 and got == prompts


# ---------------------------------------------------------------------------
# batched pack/unpack kernels
# ---------------------------------------------------------------------------


def test_pack_frames_batch_matches_frame_stream(rng):
    from repro.kernels import decode_frames_batch, encode_frames_batch

    B, cap_words, phits = 5, 24, 2
    payloads = rng.integers(0, 1 << 32, (B, cap_words),
                            dtype=np.uint64).astype(np.uint32)
    nbytes = np.asarray([0, 5, 40, 96, 64], np.int32)
    routes = np.stack([np.arange(B), (np.arange(B) + 1) % 8,
                       np.arange(B) * 10], axis=1).astype(np.int32)
    frames, n_frames = encode_frames_batch(
        jnp.asarray(payloads), jnp.asarray(nbytes), jnp.asarray(routes),
        frame_phits=phits,
    )
    for i in range(B):
        ref, nf = frame_stream(
            jnp.asarray(payloads[i]), jnp.asarray(nbytes[i]),
            frame_phits=phits,
            route=(routes[i, 0], routes[i, 1], routes[i, 2]),
        )
        np.testing.assert_array_equal(np.asarray(frames[i]), np.asarray(ref))
        assert int(n_frames[i]) == int(nf)
    # RX split kernel inverts the layout
    flat = frames.reshape(-1, frames.shape[-1])
    hdr, pay = decode_frames_batch(flat)
    np.testing.assert_array_equal(np.asarray(hdr), np.asarray(flat[:, :4]))
    np.testing.assert_array_equal(np.asarray(pay), np.asarray(flat[:, 4:]))


# ---------------------------------------------------------------------------
# sharded serving over the fabric
# ---------------------------------------------------------------------------


def test_sharded_serving_token_identical():
    import dataclasses

    from repro.configs import get_config, smoke_config
    from repro.launch.serve import (
        decode_response, encode_request, serve_requests,
        serve_requests_sharded,
    )
    from repro.models import init_params

    cfg = dataclasses.replace(smoke_config(get_config("yi-6b")), n_layers=2)
    params = init_params(cfg, jax.random.PRNGKey(0))
    rng = np.random.default_rng(0)
    wires = []
    for r in range(5):
        prompts = [
            list(map(int, rng.integers(2, cfg.vocab, int(rng.integers(8, 16)))))
            for _ in range(int(rng.integers(1, 3)))
        ]
        wires.append(encode_request(r, prompts))
    batched = serve_requests(params, cfg, wires, max_new=4, pad_to=8, slots=4)
    sharded = serve_requests_sharded(
        params, cfg, wires, max_new=4, pad_to=8, slots=4, n_shards=3
    )
    assert sharded == batched  # byte-identical response wires
    for w in sharded:
        rid, outs = decode_response(w)
        assert all(len(o) == 4 for o in outs)
