"""Typed streams: ``Stream<T>`` IDL nodes and the codecs generated from
them (``core/stream_plans.py``).

Covers the PR's regression gates: the generated ``TokenChunk`` codec is
byte-identical to the frozen golden fixture of the hand-rolled wire
format (``tests/golden/token_chunks.bin``), random stream schemas
round-trip through encode -> burst concat -> back-to-front decode, the
out-of-budget-metadata corruption flag surfaces on decode instead of
silently attributing tokens to a garbage stream, and the shipped logprob
stream — declared purely in schema JSON — rides ``ChunkLane`` /
``StreamReader`` over the fabric with no hand-written codec.

Runs on the 8 simulated host devices from ``conftest.py`` (the CI
multi-device job re-runs this file explicitly).
"""
import dataclasses
import pathlib

import numpy as np
import pytest

from repro.core import Schema, SchemaError
from repro.core.stream_plans import (
    CHUNK_META_WORDS,
    FLAG_EOS,
    Fragment,
    StreamPlan,
    decode_fragments,
    encode_fragment,
    encode_fragment_burst,
    stream_plans,
)
from repro.stream import (
    LOGPROB_STREAM_SCHEMA_JSON,
    TOKEN_STREAM_SCHEMA_JSON,
    TokenChunk,
    decode_token_chunks,
    encode_chunk_burst,
    encode_token_chunk,
    logprob_stream_plan,
    token_stream_plan,
)

GOLDEN = pathlib.Path(__file__).parent / "golden" / "token_chunks.bin"


# ---------------------------------------------------------------------------
# golden fixture: generated codec == frozen hand-rolled wire format
# ---------------------------------------------------------------------------


def _golden_chunks():
    """The deterministic chunk mix the fixture was frozen from (generated
    by the PRE-refactor hand-rolled codec; see tests/golden/)."""
    rng = np.random.default_rng(1801)
    specs = [
        (0x0001_0000, 1, False),  # serve-style (request 1, prompt 0)
        (0xFFFF_FFFF, 0, False),  # full-u32 stream id
        (7, 0, True),             # empty EOS terminator
        (0x0002_0003, 13, False),
        (42, 16, True),
        (0x1234_5678, 250, False),
    ]
    chunks, step_per_sid = [], {}
    for sid, n, eos in specs:
        step = step_per_sid.get(sid, 0)
        toks = tuple(
            int(t) for t in rng.integers(0, 1 << 32, n, dtype=np.uint64)
        )
        chunks.append(TokenChunk(sid, step, toks, eos))
        step_per_sid[sid] = step + 1
    return chunks


def test_generated_token_codec_matches_golden_fixture():
    """The ``Stream<Bytes 4>``-generated codec emits byte-for-byte the
    frozen hand-rolled wire: the batched Pallas burst, the single-chunk
    host path, and the decode round-trip all pin to the fixture."""
    golden = GOLDEN.read_bytes()
    chunks = _golden_chunks()
    assert encode_chunk_burst(chunks) == golden
    singles = b"".join(
        encode_token_chunk(c.stream_id, c.step, c.tokens, c.eos)
        for c in chunks
    )
    assert singles == golden
    got, ok = decode_token_chunks(golden)
    assert ok and got == chunks
    assert not any(c.corrupt for c in got)


def test_token_plan_is_generated_from_schema_rom():
    """``chunks.py`` ships no wire layout of its own: both plans compile
    from their schema JSON through the same schema ROM."""
    plans = stream_plans(Schema.from_json(TOKEN_STREAM_SCHEMA_JSON))
    assert set(plans) == {"tokens"}
    tok = plans["tokens"]
    assert tok.n_leaves == 1 and tok.elem_words == 1
    assert tok.leaf_nbytes == (4,)
    assert token_stream_plan() == dataclasses.replace(
        tok, id_bits=32, step_bits=16
    )
    lp = stream_plans(Schema.from_json(LOGPROB_STREAM_SCHEMA_JSON))["entries"]
    assert lp.n_leaves == 2 and lp.elem_words == 2
    assert lp.leaf_paths == ("entries.elem.tok", "entries.elem.logprob")
    assert logprob_stream_plan().leaf_nbytes == (4, 4)


def test_stream_element_must_be_fixed_size():
    bad = Schema.from_json({"M": [["s", ["Stream", ["List", ["Bytes", 2]]]]]})
    with pytest.raises(SchemaError, match="must be fixed-size"):
        stream_plans(bad)


# ---------------------------------------------------------------------------
# property: random stream schemas round-trip through the generated codec
# ---------------------------------------------------------------------------


def _random_plan(rng) -> StreamPlan:
    """A plan compiled from a random schema: 1..4 leaves of 1..12 bytes
    (single-leaf plans use a bare ``Stream<Bytes n>``, exercising both
    schema shapes and 1..3-word leaves)."""
    n_leaves = int(rng.integers(1, 5))
    nbytes = [int(rng.integers(1, 13)) for _ in range(n_leaves)]
    if n_leaves == 1:
        sj = {"M": [["s", ["Stream", ["Bytes", nbytes[0]]]]]}
    else:
        sj = {
            "M": [["s", ["Stream", ["Struct", "E"]]]],
            "E": [[f"f{i}", ["Bytes", nb]] for i, nb in enumerate(nbytes)],
        }
    return stream_plans(Schema.from_json(sj))["s"]


def _random_fragments(rng, plan: StreamPlan):
    frags = []
    for _ in range(int(rng.integers(1, 6))):
        n = int(rng.integers(0, 7))
        elems = []
        for _ in range(n):
            leaves = [
                int(rng.integers(0, 1 << min(8 * nb, 63)))
                for nb in plan.leaf_nbytes
            ]
            elems.append(leaves[0] if plan.n_leaves == 1 else tuple(leaves))
        frags.append(Fragment(
            stream_id=int(rng.integers(0, 1 << 32)),
            step=int(rng.integers(0, 1 << 16)),
            tokens=tuple(elems),
            eos=bool(rng.integers(0, 2)),
        ))
    return frags


def test_typed_stream_roundtrip_property():
    """Seeded property (always runs): for random stream schemas and
    random element sequences, generated encode -> burst concat ->
    back-to-front decode is identity, and the batched Pallas burst is
    bit-identical to concatenated single-fragment encodes."""
    rng = np.random.default_rng(0x46B)
    for _ in range(25):
        plan = _random_plan(rng)
        frags = _random_fragments(rng, plan)
        burst = encode_fragment_burst(plan, frags)
        singles = b"".join(
            encode_fragment(plan, f.stream_id, f.step, f.tokens, f.eos)
            for f in frags
        )
        assert burst == singles
        got, ok = decode_fragments(plan, burst)
        assert ok and got == frags
        assert not any(f.corrupt for f in got)


def test_typed_stream_roundtrip_hypothesis():
    """The same identity under hypothesis when the container has it
    (mirrors the seeded test above, which always runs)."""
    pytest.importorskip("hypothesis")
    from hypothesis import given, settings, strategies as st

    @st.composite
    def scenario(draw):
        nbytes = draw(st.lists(st.integers(1, 12), min_size=1, max_size=4))
        n_frags = draw(st.integers(1, 4))
        frags = []
        for i in range(n_frags):
            n = draw(st.integers(0, 5))
            elems = []
            for _ in range(n):
                leaves = [
                    draw(st.integers(0, (1 << (8 * nb)) - 1))
                    for nb in nbytes
                ]
                elems.append(leaves[0] if len(nbytes) == 1 else tuple(leaves))
            frags.append(Fragment(
                stream_id=draw(st.integers(0, (1 << 32) - 1)),
                step=draw(st.integers(0, (1 << 16) - 1)),
                tokens=tuple(elems),
                eos=draw(st.booleans()),
            ))
        return nbytes, frags

    @settings(max_examples=30, deadline=None)
    @given(scenario())
    def check(sc):
        nbytes, frags = sc
        if len(nbytes) == 1:
            sj = {"M": [["s", ["Stream", ["Bytes", nbytes[0]]]]]}
        else:
            sj = {
                "M": [["s", ["Stream", ["Struct", "E"]]]],
                "E": [[f"f{i}", ["Bytes", nb]]
                      for i, nb in enumerate(nbytes)],
            }
        plan = stream_plans(Schema.from_json(sj))["s"]
        burst = encode_fragment_burst(plan, frags)
        got, ok = decode_fragments(plan, burst)
        assert ok and got == frags

    check()


# ---------------------------------------------------------------------------
# out-of-budget metadata: per-fragment corruption flag (the PR's bugfix)
# ---------------------------------------------------------------------------


def test_decode_flags_out_of_budget_meta_per_fragment():
    """A fragment whose metadata violates the plan's declared budgets
    parses structurally but comes back ``corrupt=True`` — it is never
    silently attributed to a garbage stream, and its neighbors in the
    same burst stay clean."""
    narrow = dataclasses.replace(token_stream_plan(), step_bits=8)
    wide = token_stream_plan()  # step_bits=16: encodes what narrow rejects
    good = encode_fragment(narrow, 5, 3, (10, 11))
    bad = encode_fragment(wide, 6, 300, (12,))  # step over narrow's budget
    got, ok = decode_fragments(narrow, good + bad)
    assert ok  # structurally fine: corruption is per-fragment, not burst
    assert [f.corrupt for f in got] == [False, True]
    assert got[1].tokens == (12,)  # payload kept for diagnostics
    # the encoder refuses to EMIT what decode flags
    with pytest.raises(ValueError, match="outside the 8-bit budget"):
        encode_fragment(narrow, 6, 300, (12,))
    with pytest.raises(ValueError, match="outside the 8-bit budget"):
        encode_fragment_burst(narrow, [Fragment(6, 300, (12,))])


def test_decode_flags_unknown_flag_bits():
    """Unknown ``flags`` bits mark corruption too (a future wire revision
    must not be silently misread as EOS-or-not)."""
    plan = token_stream_plan()
    words = np.frombuffer(
        encode_fragment(plan, 1, 0, (7,)), dtype="<u4"
    ).copy()
    words[2] = FLAG_EOS | 0x8  # an undefined flag bit
    got, ok = decode_fragments(plan, words.tobytes())
    assert ok and len(got) == 1
    assert got[0].corrupt and got[0].eos  # known bits still decode


def test_reader_surfaces_meta_budget_corruption():
    """``StreamReader`` poisons exactly the stream that carried the
    out-of-budget fragment, with the ``meta-budget`` reason — CRC-clean
    deliveries included."""
    from repro.fabric import Delivery
    from repro.obs import SpanTracker
    from repro.stream import StreamReader

    plan = dataclasses.replace(token_stream_plan(), step_bits=8)
    wide = token_stream_plan()
    spans = SpanTracker()
    reader = StreamReader(spans=spans, plan=plan)
    rid = spans.start("request", req=0)
    reader.span_ids[(1, 9)] = rid
    clean = encode_fragment(plan, 4, 0, (1, 2), eos=True)
    poisoned = encode_fragment(wide, 9, 400, (3,))
    evs = reader.feed([Delivery(1, clean + poisoned)])
    assert [ev.ok for ev in evs] == [True, False]
    assert reader.streams[(1, 4)].ok and reader.streams[(1, 4)].eos
    assert not reader.streams[(1, 9)].ok
    span = spans.get(rid)
    assert span.degraded and "meta-budget" in span.reasons


# ---------------------------------------------------------------------------
# second typed stream: schema JSON -> fabric -> reader, no new codec code
# ---------------------------------------------------------------------------


def test_logprob_stream_over_fabric_schema_only():
    """The logprob stream exists only as schema JSON: its plan compiles
    through the ROM and rides the unchanged ``ChunkLane``/``StreamReader``
    over the fabric, (tok, float32-bits) tuples intact."""
    from repro.fabric import Fabric, FabricConfig
    from repro.stream import ChunkLane, StreamReader

    fab = Fabric(n_ranks=8, config=FabricConfig(frame_phits=1, credits=2))
    plan = logprob_stream_plan()
    lane = ChunkLane(fab.mailbox(3), 0, list_level=2, plan=plan)
    writers = {sid: lane.writer(sid) for sid in (10, 11)}
    rng = np.random.default_rng(7)
    sent = {sid: [] for sid in writers}
    for step in range(4):
        for sid, w in writers.items():
            entries = [
                (int(rng.integers(0, 1 << 31)),
                 int(np.float32(-rng.random()).view(np.uint32)))
                for _ in range(2)
            ]
            sent[sid].extend(entries)
            w.write(entries, eos=(step == 3))
        lane.flush()
        fab.exchange()
    reader = StreamReader(plan=plan)
    for ev in reader.feed(fab.mailbox(0).recv()):
        assert ev.ok
    assert reader.all_eos(((3, 10), (3, 11)))
    for sid, entries in sent.items():
        st = reader.streams[(3, sid)]
        assert st.ok and st.tokens == entries
        for _, bits in st.tokens:  # bit patterns survive exactly
            assert float(np.uint32(bits).view(np.float32)) <= 0.0


# ---------------------------------------------------------------------------
# serve plane: logprobs attach without touching the token stream
# ---------------------------------------------------------------------------


@pytest.fixture(scope="module")
def serve_setup():
    import jax

    from repro.configs import get_config, smoke_config
    from repro.launch.serve import encode_request
    from repro.models import init_params

    cfg = dataclasses.replace(smoke_config(get_config("yi-6b")), n_layers=2)
    params = init_params(cfg, jax.random.PRNGKey(0))
    rng = np.random.default_rng(3)
    wires = [
        encode_request(r, [
            list(map(int, rng.integers(2, cfg.vocab, 10)))
            for _ in range(int(rng.integers(1, 3)))
        ])
        for r in range(3)
    ]
    return params, cfg, wires


def test_serve_logprobs_leave_tokens_byte_identical(serve_setup):
    """Attaching the logprob side stream changes NOTHING about the token
    plane: final wires stay byte-identical, and every logprob event's
    token cross-validates against the token stream."""
    from repro.launch.serve import serve_requests_streaming

    params, cfg, wires = serve_setup
    kw = dict(max_new=4, pad_to=8, slots=4, n_shards=2)
    toks, lps = {}, {}
    base = serve_requests_streaming(params, cfg, wires, **kw)
    with_lp = serve_requests_streaming(
        params, cfg, wires, logprobs=True,
        on_token=lambda m, j, s, t: toks.setdefault((m, j), []).append(t),
        on_logprob=lambda m, j, s, t, lp: lps.setdefault(
            (m, j), []).append((t, lp)),
        **kw)
    assert with_lp == base  # byte-identical response wires
    assert set(lps) == set(toks)
    for key, pairs in lps.items():
        assert [t for t, _ in pairs] == toks[key]
        assert all(np.isfinite(lp) and lp <= 0.0 for _, lp in pairs)
