"""Batched message plane: batched structure pass, batched decode, scheduler.

The batched plan/decode must be *bit-exact* against N independent scalar
``plan_from_wire`` + ``decode_message`` calls (the jnp oracle), including
ragged prompt counts, an empty-list request, and empty inner lists; and the
continuous-batching serve loop must reproduce the seed sequential path's
tokens exactly when both pad prompts to the same length.
"""
import dataclasses

import jax
import jax.numpy as jnp
import numpy as np
import pytest

from repro.core import (
    batch_plans, build_plan, decode_batch, decode_message, plan_from_wire,
    stack_wires, wire_to_u8,
)
from repro.data.schemas import request_schema
from repro.kernels.ops import decode_batch_kernel, wires_to_u32
from repro.launch.serve import (
    decode_request, decode_request_batch, decode_response, encode_request,
    serve_request, serve_requests,
)


def _random_request_wires(rng, n=6):
    """Ragged batch: includes a zero-prompt request and an empty token list."""
    wires, truth = [], []
    n_prompts = [0, 1, 3, 5, 2, 4]
    for m in range(n):
        prompts = [
            list(map(int, rng.integers(0, 2**31, rng.integers(0, 9))))
            for _ in range(n_prompts[m % len(n_prompts)])
        ]
        truth.append((100 + m, prompts))
        wires.append(encode_request(100 + m, prompts))
    return wires, truth


def test_batch_plans_matches_individual(rng):
    schema = request_schema()
    wires, _ = _random_request_wires(rng)
    bp = batch_plans(schema, wires)
    caps = {p: bp.cap(p) for p in bp.offsets}
    for i, w in enumerate(wires):
        sp = plan_from_wire(schema, w, caps=caps)
        assert sp.wire_len == int(bp.wire_lens[i]) == len(w)
        for p in sp.offsets:
            n = sp.counts[p]
            assert n == int(bp.counts[p][i])
            np.testing.assert_array_equal(sp.offsets[p][:n], bp.offsets[p][i, :n])
        # plan_for slices back to an equivalent scalar plan
        one = bp.plan_for(i)
        assert one.counts == sp.counts


def test_decode_batch_matches_scalar_oracle(rng):
    schema = request_schema()
    wires, _ = _random_request_wires(rng)
    bp = batch_plans(schema, wires)
    caps = {p: bp.cap(p) for p in bp.offsets}
    vals = decode_batch(jnp.asarray(stack_wires(wires)), bp)
    for i, w in enumerate(wires):
        ref = decode_message(wire_to_u8(w), plan_from_wire(schema, w, caps=caps))
        for p, v in vals.items():
            n = int(bp.counts[p][i])
            np.testing.assert_array_equal(np.asarray(v[i, :n]), np.asarray(ref[p][:n]))


def test_decode_batch_kernel_matches_oracle(rng):
    schema = request_schema()
    wires, _ = _random_request_wires(rng)
    bp = batch_plans(schema, wires)
    oracle = decode_batch(
        jnp.asarray(stack_wires(wires, pad_to=-(-max(len(w) for w in wires) // 4) * 4)),
        bp,
    )
    u32, row_bytes = wires_to_u32(wires)
    got = decode_batch_kernel(u32, row_bytes, bp)
    for p in oracle:
        for i in range(len(wires)):
            n = int(bp.counts[p][i])
            np.testing.assert_array_equal(
                np.asarray(got[p][i, :n]), np.asarray(oracle[p][i, :n])
            )


def test_decode_request_batch_roundtrip(rng):
    wires, truth = _random_request_wires(rng)
    assert decode_request_batch(wires) == truth
    # and agrees with the streaming-FSM scalar DES
    for w, t in zip(wires, truth):
        assert decode_request(w) == t


def test_plan_overflow_raises(rng):
    """Both structure passes must refuse an undersized cap (not truncate)."""
    schema = request_schema()
    msg = {"req_id": 1, "prompts": [{"tokens": [1, 2, 3, 4, 5]}]}
    wire = encode_request(1, [[1, 2, 3, 4, 5]])
    caps = {"prompts.elem.tokens.elem": 2}
    with pytest.raises(ValueError, match="exceed"):
        build_plan(schema, msg, caps=caps)
    with pytest.raises(ValueError, match="exceed"):
        plan_from_wire(schema, wire, caps=caps)
    with pytest.raises(ValueError, match="exceed"):
        batch_plans(schema, [wire], caps=caps)


def test_batch_plans_rejects_corrupt_count(rng):
    """A corrupted count field must fail that batch loudly (ValueError),
    not index numpy out of bounds or silently mis-decode."""
    schema = request_schema()
    good = encode_request(1, [[1, 2, 3]])
    bad = bytearray(encode_request(2, [[4, 5, 6]]))
    bad[8] = 0xFF  # prompts count (after the 8-byte req_id) -> 255 prompts
    with pytest.raises(ValueError, match="truncated or corrupt"):
        batch_plans(schema, [good, bytes(bad)])


@pytest.fixture(scope="module")
def tiny_serve():
    from repro.configs import get_config, smoke_config
    from repro.models import init_params

    cfg = dataclasses.replace(smoke_config(get_config("yi-6b")), n_layers=1)
    params = init_params(cfg, jax.random.PRNGKey(0))
    return params, cfg


def test_scheduler_matches_sequential(tiny_serve, rng):
    """More sequences than slots -> admit/evict churn; outputs must equal
    the seed's per-request loop (same prompt pad length on both sides)."""
    params, cfg = tiny_serve
    pad_to = 8  # prompts >= 8 so the seed path also pads to exactly 8
    wires = [
        encode_request(r, [
            list(map(int, rng.integers(2, cfg.vocab, 8 + int(rng.integers(0, 4)))))
            for _ in range(2)
        ])
        for r in range(3)
    ]
    seq = [serve_request(params, cfg, w, max_new=4, pad_to=pad_to) for w in wires]
    bat = serve_requests(params, cfg, wires, max_new=4, pad_to=pad_to, slots=2)
    assert [decode_response(w) for w in bat] == [decode_response(w) for w in seq]


def test_serve_empty_request(tiny_serve):
    """A request with zero prompts flows through the whole plane — and
    through the sequential baseline."""
    params, cfg = tiny_serve
    wires = [encode_request(9, []), encode_request(10, [[5, 6, 7, 8]])]
    resp = serve_requests(params, cfg, wires, max_new=2, pad_to=8, slots=2)
    rid, outs = decode_response(resp[0])
    assert (rid, outs) == (9, [])
    rid, outs = decode_response(resp[1])
    assert rid == 10 and len(outs) == 1 and len(outs[0]) == 2
    assert decode_response(serve_request(params, cfg, wires[0])) == (9, [])


@pytest.mark.parametrize("arch", ["phi-3-vision-4.2b", "whisper-tiny"])
def test_scheduler_other_families(arch, rng):
    """The slot cache must match prefill's geometry for families whose KV
    grows beyond prompt_cap + max_new (vlm vision prefix, encdec enc_kv)."""
    from repro.configs import get_config, smoke_config
    from repro.models import init_params

    cfg = dataclasses.replace(smoke_config(get_config(arch)), n_layers=1)
    params = init_params(cfg, jax.random.PRNGKey(0))
    wires = [encode_request(0, [list(map(int, rng.integers(2, cfg.vocab, 8)))])]
    resp = serve_requests(params, cfg, wires, max_new=3, pad_to=8, slots=2)
    rid, outs = decode_response(resp[0])
    assert rid == 0 and len(outs) == 1 and len(outs[0]) == 3
