"""Observability plane: metrics registry, on-device fabric counters,
static-vs-observed load drift, trace export, and telemetry-off identity.

The counter invariants here are the PR's acceptance criteria:

* counters are exact, not sampled — delivered frames reported by the
  scan-carry counter block equal ``Fabric.frames_routed`` exactly;
* for deterministic workloads the OBSERVED per-(link, direction) load
  matrix equals ``analysis.comm.demand_link_loads``'s static prediction
  bit-for-bit, so any divergence (``Fabric.load_drift()``) is a real
  routing bug or fault — asserted both ways with a seeded ``tx_hook``
  misroute;
* the fused single-jit tick and the three-program path accumulate
  bit-identical counter blocks (the counters are order-independent event
  counts, so engine choice and queue layout cannot skew them);
* attaching a registry/trace to the streaming serve loop changes ZERO
  response bytes.

Runs on the 8 simulated host devices from ``conftest.py``.
"""
import dataclasses
import json

import jax
import numpy as np
import pytest

from repro.fabric import Fabric, FabricConfig
from repro.obs import (
    ClassWindows,
    MetricsRegistry,
    TraceRecorder,
    format_key,
    validate_snapshot,
    validate_trace,
    window_stats,
)


# ---------------------------------------------------------------------------
# metrics registry
# ---------------------------------------------------------------------------


def test_registry_basics_and_flat_keys():
    m = MetricsRegistry()
    m.counter("f.sent", axis=0).add(3)
    m.counter("f.sent", axis=0).add(2)  # get-or-create: same instance
    m.counter("f.sent", axis=1).add(7)
    m.gauge("q.depth").set(4)
    m.histogram("lat", base=1.0).observe(5.0)
    m.series("ttft").append(0.25)
    flat = m.flat()
    assert flat[format_key("f.sent", {"axis": 0})] == 5
    assert flat["f.sent{axis=1}"] == 7
    assert flat["q.depth"] == 4
    assert flat["lat"]["count"] == 1
    assert flat["ttft"] == [0.25]


def test_registry_kind_conflict_and_negative_counter_raise():
    m = MetricsRegistry()
    m.counter("x").add(1)
    with pytest.raises(ValueError):
        m.gauge("x")  # a name is pinned to one metric type
    with pytest.raises(ValueError):
        m.counter("x").add(-1)  # counters are monotonic


def test_histogram_log2_buckets_exact():
    from repro.obs import Histogram

    h = Histogram(base=1.0, n_buckets=8)
    for v, bucket in ((0.5, 0), (1.0, 0), (1.5, 1), (2.0, 1), (3.0, 2),
                      (1000.0, 7)):  # overflow clamps to the last bucket
        before = list(h.buckets)
        h.observe(v)
        assert h.buckets[bucket] == before[bucket] + 1, (v, bucket)
    assert h.count == sum(h.buckets) == 6
    assert h.min == 0.5 and h.max == 1000.0
    assert h.bounds()[0] == 1.0 and h.bounds()[2] == 4.0


def test_snapshot_round_trips_and_readers_ignore_unknown_keys():
    m = MetricsRegistry()
    m.counter("a", k=1).add(2)
    m.histogram("h").observe(3.0)
    snap = json.loads(m.to_json())
    assert validate_snapshot(snap) == []
    # forward-compat: a newer writer may add keys; validators/readers must
    # ignore what they don't know rather than reject the document
    snap["future_field"] = {"x": 1}
    snap["metrics"][0]["future_key"] = "y"
    assert validate_snapshot(snap) == []
    # ...but real schema violations are caught
    bad = json.loads(m.to_json())
    bad["metrics"][1]["buckets"][0] += 1  # count != sum(buckets)
    assert validate_snapshot(bad)


# ---------------------------------------------------------------------------
# satellite: ONE shared arrive-window implementation
# ---------------------------------------------------------------------------


def test_arrive_window_is_one_shared_implementation():
    """``stream.plane.arrive_stats`` IS ``obs.metrics.window_stats`` (the
    module-level alias), and ``ClassWindows`` — what
    ``Fabric.class_arrive_stats`` serves — produces byte-identical dicts
    for the same samples.  The two ends of the backpressure loop can never
    disagree on what "p95" means."""
    from repro.stream import plane

    assert plane.arrive_stats is window_stats
    samples = {0: [3, 5, 2, 9, 4, 1, 1, 12], 1: [7, 7, 8]}
    cw = ClassWindows(maxlen=256)
    for cls, vals in samples.items():
        for v in vals:
            cw.record(cls, v)
    assert cw.stats() == {c: window_stats(v) for c, v in samples.items()}


def test_fabric_and_reader_arrive_stats_identical():
    """End to end: single-token chunks (one chunk per message) make the
    fabric's per-message window and the reader's per-chunk window see the
    same arrive steps — the per-class stats must match exactly."""
    from repro.stream import StreamReader, encode_token_chunk

    fab = Fabric(n_ranks=4, config=FabricConfig(
        frame_phits=16, credits=4, qos_weights=(2, 1)))
    boxes = [fab.mailbox(r) for r in range(4)]
    reader = StreamReader()
    for step in range(3):
        for src in (1, 2, 3):
            wire = encode_token_chunk(src, step, [100 + step], eos=(step == 2))
            boxes[src].send(0, wire, list_level=1 + (src % 2))
        fab.exchange()
        reader.feed(boxes[0].recv())
    fab_stats = fab.class_arrive_stats(0)
    reader_stats = reader.class_arrive_stats()
    # fabric keys by level % n_classes; fold the reader's streams the same way
    per = {}
    for st in reader.streams.values():
        per.setdefault(st.level % fab.n_classes, []).extend(st.arrive_steps)
    assert fab_stats == {c: window_stats(v) for c, v in sorted(per.items())}
    assert reader_stats  # and the reader's own per-level view is populated


# ---------------------------------------------------------------------------
# on-device counters: exactness + static-vs-observed drift
# ---------------------------------------------------------------------------


def _all_to_all(fab, n=None, nbytes=17):
    n = n or fab.n_ranks
    boxes = [fab.mailbox(r) for r in range(n)]
    for s in range(n):
        for d in range(n):
            if s != d:
                boxes[s].send(d, bytes([s, d]) * nbytes)
    fab.exchange()
    return boxes


def test_counters_exact_delivered_and_observed_equals_static():
    """Delivered counter == ``frames_routed`` exactly; the observed
    per-(ring, direction) load matrix equals the static
    ``analysis.comm.demand_link_loads`` prediction frame-for-frame, so
    ``load_drift()`` is empty."""
    fab = Fabric(n_ranks=8, config=FabricConfig(
        frame_phits=2, credits=2, qos_weights=(3, 1)))
    _all_to_all(fab)
    ctr = fab.counters_total()
    from repro.obs.counters import global_index

    delivered = int(ctr[:, global_index(1, "delivered")].sum())
    assert delivered == fab.frames_routed > 0
    assert int(ctr[:, global_index(1, "crc_fail")].sum()) == 0
    observed = fab.observed_link_loads()
    expected = fab.expected_link_loads()
    assert observed == expected
    assert fab.load_drift() == {}


@pytest.mark.parametrize("routing", ["dimension", "shortest"])
def test_observed_loads_match_static_on_2d_mesh(routing):
    """Both routing disciplines: static demand == observed, per axis, per
    ring, per direction, on a (4, 2) mesh."""
    mesh = jax.make_mesh((4, 2), ("fx", "fy"))
    fab = Fabric(mesh=mesh, config=FabricConfig(
        frame_phits=2, credits=2, routing=routing))
    _all_to_all(fab, n=8)
    assert fab.load_drift() == {}
    # and the matrices are non-trivial on both axes
    obs_x, obs_y = fab.observed_link_loads()
    assert sum(obs_x.values()) > 0 and sum(obs_y.values()) > 0


@pytest.mark.parametrize("routing", ["dimension", "shortest"])
def test_counters_bit_identical_fused_vs_three_program(routing):
    """The fused one-jit tick and the three-program fallback accumulate the
    SAME counter block bit-for-bit: counters are order-independent event
    counts, so engine choice cannot skew observability."""
    rng = np.random.default_rng(7)
    sends = []
    for s in range(8):
        for _ in range(2):
            d = int(rng.integers(0, 8))
            if d == s:
                continue
            w = rng.integers(0, 256, int(rng.integers(1, 60)),
                             dtype=np.uint8).tobytes()
            sends.append((s, d, w, int(rng.integers(1, 4))))
    cfg = dict(frame_phits=2, credits=2, routing=routing,
               qos_weights=(2, 1))
    totals = []
    for fused in (True, False):
        fab = Fabric(n_ranks=8, config=FabricConfig(fused=fused, **cfg))
        boxes = [fab.mailbox(r) for r in range(8)]
        for s, d, w, lvl in sends:
            boxes[s].send(d, w, list_level=lvl)
        fab.exchange()
        for r in range(8):
            boxes[r].recv()
        totals.append(fab.counters_total())
        assert fab.load_drift() == {}
    assert np.array_equal(totals[0], totals[1])


def test_seeded_misroute_shows_up_as_load_drift():
    """Fault injection: a ``tx_hook`` that rewrites one frame's dst byte
    back to its src (a misroute the static analysis cannot know about)
    must surface as a nonzero static-vs-observed divergence."""
    from repro.fabric.frames import HDR_ROUTE

    def run(hook):
        fab = Fabric(n_ranks=8, config=FabricConfig(frame_phits=2, credits=2))
        fab.tx_hook = hook
        _all_to_all(fab)
        return fab

    identity = run(lambda tx, v: tx)
    assert identity.load_drift() == {}  # hook path itself drifts nothing

    def misroute(tx, tx_valid):
        tx = np.array(tx)
        w = int(tx[1, 0, HDR_ROUTE])
        src = (w >> 24) & 0x7F
        tx[1, 0, HDR_ROUTE] = (w & ~0xFF0000) | (src << 16)
        return tx

    drift = run(misroute).load_drift()
    assert drift  # the misroute is visible as expected != observed
    assert all(exp != obs for exp, obs in drift.values())


def test_recompile_counter_machine_readable_and_flat_after_warmup():
    """Satellite: tick recompiles surface as a labeled counter.  The same
    traffic shape re-exchanged must not grow it (steady-state serving
    never recompiles silently); a new shape bucket adds exactly one."""
    fab = Fabric(n_ranks=8, config=FabricConfig(frame_phits=2, credits=2))

    def recompiles():
        return sum(
            v for k, v in fab.metrics.flat().items()
            if k.startswith("fabric.tick.recompiles")
        )

    for tick in range(3):
        for s in range(4):
            fab.mailbox(s).send((s + 2) % 8, bytes([tick + 1, s]) * 16)
        fab.exchange()
        assert recompiles() == 1, f"tick {tick}"
    fab.mailbox(0).send(1, bytes(4096))  # much longer wire: new bucket
    fab.exchange()
    assert recompiles() == 2


# ---------------------------------------------------------------------------
# trace export
# ---------------------------------------------------------------------------


def test_trace_recorder_emits_valid_chrome_trace(tmp_path):
    tr = TraceRecorder()
    tr.name_track(0, "fabric", tid=1, thread="ticks")
    with tr.span("tick", cat="fabric", args={"frames": 4}):
        tr.instant("chunk.arrive", pid=1, args={"stream": 2})
    tr.counter("inflight", {"frames": 3.0})
    obj = tr.to_json()
    assert validate_trace(obj) == []
    assert obj["displayTimeUnit"] == "ms"
    phases = {e["ph"] for e in obj["traceEvents"]}
    assert {"X", "i", "C", "M"} <= phases
    span = next(e for e in obj["traceEvents"] if e["ph"] == "X")
    assert span["dur"] >= 0 and span["args"]["frames"] == 4
    out = tmp_path / "t.json"
    tr.save(out)
    assert validate_trace(json.loads(out.read_text())) == []
    # bare-list form (what some tools emit) validates too
    assert validate_trace(obj["traceEvents"]) == []
    assert validate_trace({"nope": 1})  # and garbage is rejected


def test_obs_cli_validates_artifacts(tmp_path):
    from repro.obs.__main__ import main as obs_main

    m = MetricsRegistry()
    m.counter("c").add(1)
    mfile = tmp_path / "m.json"
    mfile.write_text(m.to_json())
    tr = TraceRecorder()
    with tr.span("s"):
        pass
    tfile = tmp_path / "t.json"
    tr.save(tfile)
    assert obs_main([str(mfile), "--validate"]) == 0
    assert obs_main([str(tfile), "--validate"]) == 0
    bad = tmp_path / "bad.json"
    bad.write_text('{"what": 1}')
    assert obs_main([str(bad), "--validate"]) != 0


# ---------------------------------------------------------------------------
# serving-plane telemetry: byte-identity + required series
# ---------------------------------------------------------------------------


@pytest.fixture(scope="module")
def serve_setup():
    from repro.configs import get_config, smoke_config
    from repro.launch.serve import encode_request
    from repro.models import init_params

    cfg = dataclasses.replace(smoke_config(get_config("yi-6b")), n_layers=2)
    params = init_params(cfg, jax.random.PRNGKey(0))
    rng = np.random.default_rng(0)
    wires = []
    for r in range(3):
        prompts = [
            list(map(int, rng.integers(2, cfg.vocab, int(rng.integers(8, 16)))))
            for _ in range(int(rng.integers(1, 3)))
        ]
        wires.append(encode_request(r, prompts))
    return params, cfg, wires


def test_streaming_serve_telemetry_is_byte_invisible(serve_setup):
    """Attaching a full registry + trace recorder to the streamed serve
    loop changes ZERO response bytes, and the snapshot contains the
    acceptance series: TTFT, tokens/s, backpressure p95, fabric frames."""
    from repro.launch.serve import serve_requests_streaming

    params, cfg, wires = serve_setup
    kw = dict(max_new=4, pad_to=8, slots=4, n_shards=2)
    plain = serve_requests_streaming(params, cfg, wires, **kw)
    metrics, trace = MetricsRegistry(), TraceRecorder()
    observed = serve_requests_streaming(
        params, cfg, wires, metrics=metrics, trace=trace, **kw)
    assert observed == plain  # telemetry must never touch tokens
    snap = metrics.snapshot()
    assert validate_snapshot(snap) == []
    names = {m["name"] for m in snap["metrics"]}
    for required in ("serve.ttft_s", "serve.ttft_s.series",
                     "serve.tokens_per_s", "serve.backpressure.p95",
                     "serve.tick.tokens", "serve.tokens",
                     "batcher.admitted", "batcher.occupancy",
                     "batcher.steps", "stream.reader.chunks",
                     "stream.reader.tokens", "fabric.frames.delivered",
                     "fabric.ticks"):
        assert required in names, required
    flat = metrics.flat()
    assert flat["serve.tokens"] > 0
    assert flat["serve.ttft_s.series"]  # at least one first token recorded
    assert validate_trace(trace.to_json()) == []
    ev_names = {e["name"] for e in trace.events}
    assert "serve.tick" in ev_names and "stream.chunk" in ev_names


# ---------------------------------------------------------------------------
# satellites: histogram quantiles, snapshot diff, SLO gates, CLI
# ---------------------------------------------------------------------------


def test_histogram_quantile_pinned_against_exact_samples():
    """Interpolated log2-bucket quantiles vs exact-sample references:
    within one bucket width of the true value, exact at the extremes."""
    import math

    from repro.obs import Histogram

    h = Histogram(base=1.0, n_buckets=16)
    samples = [float(v) for v in range(1, 11)]  # 1..10
    for v in samples:
        h.observe(v)
    assert h.quantile(0.0) == 1.0  # clamps to observed min
    assert h.quantile(1.0) == 10.0  # ...and max
    assert h.quantile(0.5) == 5.0  # pinned: ceil-rank 5 lands mid-bucket
    for q in (0.25, 0.75, 0.9, 0.95):
        exact = samples[min(len(samples) - 1,
                            max(0, math.ceil(q * len(samples)) - 1))]
        got = h.quantile(q)
        lo, hi = 2 ** (math.floor(math.log2(exact))), \
            2 ** (math.ceil(math.log2(exact)) or 1)
        assert lo / 2 <= got <= hi * 2, (q, exact, got)
    assert Histogram().quantile(0.5) is None  # empty -> None
    with pytest.raises(ValueError):
        h.quantile(1.5)


def test_quantile_from_buckets_interpolation_and_overflow():
    from repro.obs import quantile_from_buckets

    # one bucket (1, 2], 4 samples: rank interpolates within the bucket
    assert quantile_from_buckets(1.0, [0, 4], 4, 1.2, 2.0, 0.5) == \
        pytest.approx(1.5)
    # overflow bucket is capped at the observed max, not 2^n
    v = quantile_from_buckets(1.0, [0, 0, 10], 10, 3.0, 6.0, 0.99)
    assert v is not None and v <= 6.0


def test_diff_snapshots_structure_and_render():
    from repro.obs import diff_snapshots, render_diff

    a = MetricsRegistry()
    a.counter("x").add(2)
    a.counter("gone").add(1)
    b = MetricsRegistry()
    b.counter("x").add(3)
    b.gauge("new.g").set(7)
    d = diff_snapshots(a.snapshot(), b.snapshot())
    assert list(d["added"]) == ["new.g"] and list(d["removed"]) == ["gone"]
    assert d["changed"]["x"]["delta"] == 1
    assert d["changed"]["x"]["ratio"] == pytest.approx(1.5)
    text = render_diff(d)
    assert "+ new.g" in text and "- gone" in text and "~ x" in text
    same = diff_snapshots(a.snapshot(), a.snapshot())
    assert not (same["added"] or same["removed"] or same["changed"])
    assert "snapshots agree" in render_diff(same)


def test_evaluate_slo_pass_fail_burn_and_missing_signal():
    from repro.obs import evaluate_slo

    m = MetricsRegistry()
    for v in (0.1, 0.2, 0.3, 0.4):
        m.series("serve.ttft_s.series").append(v)
    m.gauge("serve.tokens_per_s").set(50.0)
    m.gauge("fabric.load_drift.entries").set(0)
    snap = m.snapshot()
    rep = evaluate_slo("ttft_p95_s=0.5,tokens_per_s_min=10,drift_free",
                       snapshot=snap)
    assert rep.ok and not rep.violations()
    by = {r.name: r for r in rep.results}
    assert by["ttft_p95_s"].observed == pytest.approx(0.4)  # ceil-rank p95
    assert by["ttft_p95_s"].burn_rate == pytest.approx(0.8)
    assert by["tokens_per_s_min"].burn_rate == pytest.approx(0.2)
    # violation: burn > 1 and ok=False; missing signal FAILS, never passes
    rep2 = evaluate_slo({"ttft_p95_s": 0.2, "max:absent.metric": 1},
                        snapshot=snap)
    assert not rep2.ok
    by2 = {r.name: r for r in rep2.results}
    assert by2["ttft_p95_s"].burn_rate == pytest.approx(2.0)
    assert by2["max:absent.metric"].observed is None
    assert "VIOLATED" in rep2.render_text()
    # generic flat-key bounds work on plain values dicts (bench metrics)
    rep3 = evaluate_slo("min:fabric.smoke_frames_per_s=10",
                        values={"fabric.smoke_frames_per_s": 100.0})
    assert rep3.ok
    # unknown objectives fail loudly with a hint
    assert not evaluate_slo({"not_a_thing": 1}, snapshot=snap).ok


def test_parse_slo_forms(tmp_path):
    from repro.obs import parse_slo

    assert parse_slo("a=1.5,drift_free") == {"a": 1.5, "drift_free": True}
    assert parse_slo({"k": 2}) == {"k": 2}
    p = tmp_path / "slo.json"
    p.write_text('{"ttft_p95_s": 0.25}')
    assert parse_slo(str(p)) == {"ttft_p95_s": 0.25}
    with pytest.raises(ValueError):
        parse_slo("  ")


def test_obs_cli_diff_slo_attribution_history(tmp_path, capsys):
    from repro.obs.__main__ import main as obs_main

    a = MetricsRegistry()
    a.counter("x").add(1)
    afile = tmp_path / "a.json"
    afile.write_text(a.to_json())
    b = MetricsRegistry()
    b.counter("x").add(5)
    bfile = tmp_path / "b.json"
    bfile.write_text(b.to_json())
    assert obs_main(["diff", str(afile), str(bfile)]) == 0
    assert obs_main(["diff", str(afile), str(bfile),
                     "--fail-on-change"]) == 1
    assert obs_main(["diff", str(afile), str(afile),
                     "--fail-on-change"]) == 0
    # slo: exit 0 on pass, 1 on violation
    assert obs_main(["slo", "max:x=10", "--metrics", str(afile)]) == 0
    assert obs_main(["slo", "max:x=0.5", "--metrics", str(bfile)]) == 1
    # attribution: render a spans export
    from repro.obs import SpanTracker

    sp = SpanTracker()
    sp.set_tick(0)
    rid = sp.start("request", cls=1)
    sp.event(rid, "serve.ingress")
    sp.add_component(rid, "fabric.transit", 3)
    sp.set_tick(2)
    sp.event(rid, "serve.first_token")
    sp.finish(rid)
    sfile = tmp_path / "spans.json"
    sfile.write_text(json.dumps(sp.export()))
    assert obs_main(["attribution", str(sfile)]) == 0
    out = capsys.readouterr().out
    assert "ttft_ticks" in out and "request attribution" in out
    # history: tabulate bench_history.jsonl rows
    hfile = tmp_path / "hist.jsonl"
    hfile.write_text(
        json.dumps({"git_sha": "abc123def456", "timestamp": "t0",
                    "metrics": {"fabric": {"smoke_frames_per_s": 1000.0}}})
        + "\n"
        + json.dumps({"git_sha": "def456abc789", "timestamp": "t1",
                      "metrics": {"fabric": {"smoke_frames_per_s": 1100.0}}})
        + "\n")
    assert obs_main(["history", str(hfile)]) == 0
    out = capsys.readouterr().out
    assert "2 run(s)" in out and "fabric.smoke_frames_per_s" in out
    assert obs_main(["history", str(tmp_path / "missing.jsonl")]) == 2
    # the legacy single-file form is untouched by subcommand dispatch
    assert obs_main([str(afile), "--validate"]) == 0


def test_batcher_metrics_admit_evict_occupancy(serve_setup):
    from repro.runtime.scheduler import ContinuousBatcher, SchedulerConfig

    params, cfg, _ = serve_setup
    m = MetricsRegistry()
    b = ContinuousBatcher(
        params, cfg, SchedulerConfig(slots=2, prompt_cap=8, max_new=2),
        metrics=m)
    for i in range(3):
        b.submit(i, list(range(2, 8)))
    out = b.run()
    assert len(out) == 3
    flat = m.flat()
    assert flat["batcher.admitted"] == 3
    assert flat["batcher.evicted"] == 3
    assert flat["batcher.steps"] == b.steps_run
    # gauges reflect the LAST dispatched tick: the straggler ran alone
    # with nothing left queued
    assert flat["batcher.occupancy"] == 1
    assert flat["batcher.queue_depth"] == 0
