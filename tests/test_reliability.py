"""Reliable delivery: ARQ retransmission under seeded chaos, corruption
postures, failure-aware serving, and the static rules that keep the knob
set coherent.

The contract under test is the strong one: with ``FabricConfig(arq=True)``
delivered messages are BYTE-IDENTICAL and in-order per (src, dst) stream
even under seeded drop/corrupt/duplicate faults — on both tick engines —
and a rank blackout makes a serve COMPLETE (suspect detection +
re-placement) instead of hanging.  Runs on the 8 simulated host devices
from ``conftest.py``."""
import dataclasses

import numpy as np
import pytest

from repro.fabric import (
    Fabric,
    FabricConfig,
    FabricCorruption,
    FaultPlan,
    SEQ_MOD,
    parse_chaos,
)
from repro.fabric.frames import HDR_ROUTE


# ---------------------------------------------------------------------------
# helpers
# ---------------------------------------------------------------------------


def _wires(rng, n, lo=10, hi=200):
    return [bytes(map(int, rng.integers(0, 256, int(rng.integers(lo, hi)))))
            for _ in range(n)]


def _sends(wires):
    """A fixed multi-pair, multi-frame workload over 8 ranks."""
    pairs = [(0, 4), (0, 4), (1, 5), (3, 2), (6, 0), (0, 4), (7, 1)]
    return [(s, d, wires[i % len(wires)], 1 + i % 3)
            for i, (s, d) in enumerate(pairs)]


def _deliver(fab, sends, max_ticks=300):
    """Send everything, tick until every message landed (or give up),
    return {(src, dst): [Delivery, ...]} in arrival order."""
    for s, d, w, lvl in sends:
        fab.send(s, d, w, list_level=lvl)
    want = len(sends)
    got = {}
    n = 0
    for _ in range(max_ticks):
        fab.exchange()
        for r in range(fab.n_ranks):
            for d in fab.drain(r):
                got.setdefault((d.src, r), []).append(d)
                n += 1
        if n >= want:
            break
    return got


def _streams(got):
    """Comparable view: per-stream ordered (wire, ok, level) tuples."""
    return {k: [(d.wire, d.ok, d.list_level) for d in v]
            for k, v in sorted(got.items())}


def _counters(fab, prefix="fabric.arq."):
    out = {}
    for m in fab.metrics.snapshot()["metrics"]:
        if m["type"] == "counter" and m["name"].startswith(prefix):
            out[m["name"]] = out.get(m["name"], 0) + m["value"]
    return out


def _cfg(**kw):
    kw.setdefault("frame_phits", 2)
    kw.setdefault("credits", 2)
    kw.setdefault("arq", True)
    return FabricConfig(**kw)


# ---------------------------------------------------------------------------
# ARQ byte-identity under seeded faults (the tentpole contract)
# ---------------------------------------------------------------------------


@pytest.mark.parametrize("fused", [True, False], ids=["fused", "programs"])
@pytest.mark.parametrize(
    "plan",
    [
        FaultPlan(seed=3, drop=0.08),
        FaultPlan(seed=5, corrupt=0.08),
        FaultPlan(seed=11, duplicate=0.3),
        FaultPlan(seed=2, drop=0.05, corrupt=0.04, duplicate=0.1,
                  reorder=0.5),
    ],
    ids=["drop", "corrupt", "duplicate", "mixed"],
)
def test_arq_identity_under_seeded_faults(rng, plan, fused):
    wires = _wires(rng, 5)
    sends = _sends(wires)
    clean = _streams(_deliver(
        Fabric(n_ranks=8, config=_cfg(fused=fused)), sends))
    fab = Fabric(n_ranks=8, config=_cfg(fused=fused))
    fab.faults = plan
    faulty = _streams(_deliver(fab, sends))
    # byte-identical, in-order per stream, every delivery clean
    assert faulty == clean
    assert all(ok for v in faulty.values() for _, ok, _ in v)


def test_arq_shortest_and_dimension_routing(rng):
    wires = _wires(rng, 4)
    sends = _sends(wires)
    plan = FaultPlan(seed=9, drop=0.06, corrupt=0.04)
    for routing in ("shortest", "dimension"):
        clean = _streams(_deliver(
            Fabric(n_ranks=8, config=_cfg(routing=routing)), sends))
        fab = Fabric(n_ranks=8, config=_cfg(routing=routing))
        fab.faults = plan
        assert _streams(_deliver(fab, sends)) == clean, routing


def test_fused_vs_three_program_identical_under_same_faults(rng):
    """One seeded FaultPlan, two tick engines: the post-fault frame lists
    are planned host-side from pure (seed, tick, src, dst, seq) hashes, so
    BOTH engines must see the identical fault sequence and deliver the
    identical bytes."""
    wires = _wires(rng, 5)
    sends = _sends(wires)
    plan = FaultPlan(seed=21, drop=0.07, corrupt=0.05, duplicate=0.15)
    got = {}
    for fused in (True, False):
        fab = Fabric(n_ranks=8, config=_cfg(fused=fused))
        fab.faults = plan
        got[fused] = _streams(_deliver(fab, sends))
    assert got[True] == got[False]


def test_duplicate_storm_suppressed(rng):
    """Every frame duplicated: deliveries stay exact (no doubled messages)
    and the seq window visibly suppressed the copies."""
    wires = _wires(rng, 3)
    sends = _sends(wires)
    clean = _streams(_deliver(Fabric(n_ranks=8, config=_cfg()), sends))
    fab = Fabric(n_ranks=8, config=_cfg())
    fab.faults = FaultPlan(seed=1, duplicate=1.0)
    assert _streams(_deliver(fab, sends)) == clean
    assert _counters(fab)["fabric.arq.dup_suppressed"] > 0


def test_zero_fault_arq_is_invisible(rng):
    """With no faults, arq=True delivers exactly what arq=False delivers,
    and every recovery counter reads 0 (materialized, not absent — the
    max_retransmit_ratio SLO needs the zeros)."""
    wires = _wires(rng, 4)
    sends = _sends(wires)
    legacy = _streams(_deliver(
        Fabric(n_ranks=8, config=_cfg(arq=False)), sends))
    fab = Fabric(n_ranks=8, config=_cfg())
    assert _streams(_deliver(fab, sends)) == legacy
    ctr = _counters(fab)
    for name in ("retransmits", "nacks", "timeouts", "dup_suppressed",
                 "crc_dropped", "aborts", "evicted", "replays", "skips"):
        assert ctr[f"fabric.arq.{name}"] == 0, (name, ctr)


def test_arq_identity_property(rng):
    """Property form: random seeds x fault rates, fused engine.  The
    baseline is computed once (same sends every example)."""
    hyp = pytest.importorskip("hypothesis")
    from hypothesis import strategies as st

    wires = _wires(rng, 4)
    sends = _sends(wires)
    clean = _streams(_deliver(Fabric(n_ranks=8, config=_cfg()), sends))

    @hyp.settings(max_examples=8, deadline=None)
    @hyp.given(
        seed=st.integers(0, 2**31 - 1),
        drop=st.floats(0.0, 0.12),
        corrupt=st.floats(0.0, 0.1),
        dup=st.floats(0.0, 0.25),
    )
    def prop(seed, drop, corrupt, dup):
        fab = Fabric(n_ranks=8, config=_cfg())
        fab.faults = FaultPlan(seed=seed, drop=drop, corrupt=corrupt,
                               duplicate=dup)
        faulty = _streams(_deliver(fab, sends))
        assert faulty == clean

    prop()


# ---------------------------------------------------------------------------
# on_corrupt postures (fabric + stream reader)
# ---------------------------------------------------------------------------


def test_on_corrupt_flag_and_raise(rng):
    """arq=False + 100% payload corruption: flag returns the damage,
    raise refuses it with the inbox left intact."""
    fab = Fabric(n_ranks=8, config=_cfg(arq=False))
    fab.faults = FaultPlan(seed=4, corrupt=1.0)
    fab.send(0, 4, bytes(rng.integers(0, 256, 64)))
    fab.exchange()
    with pytest.raises(FabricCorruption, match="corrupt deliveries"):
        fab.drain(4, on_corrupt="raise")
    got = fab.drain(4)  # inbox survived the raise
    assert len(got) == 1 and not got[0].ok
    with pytest.raises(ValueError, match="flag"):
        fab.drain(4, on_corrupt="bogus")


def test_on_corrupt_retry_needs_arq():
    fab = Fabric(n_ranks=8, config=_cfg(arq=False))
    with pytest.raises(ValueError, match="arq=True"):
        fab.drain(0, on_corrupt="retry")


def test_on_corrupt_retry_replays_from_sender_buffer(rng):
    """A frame whose ORIGINAL seq is corrupted on every (re)transmit can
    never be repaired by ARQ — the sender aborts, the receiver skips past
    the gap and delivers the partial flagged.  drain(on_corrupt='retry')
    then asks the sender to replay its buffered copy under a FRESH seq,
    which the seq-keyed corruptor leaves alone, so the clean bytes arrive
    a tick later."""
    fab = Fabric(n_ranks=8, config=_cfg(
        fused=False, retransmit_timeout=2, max_retries=1))
    wire = bytes(map(int, rng.integers(0, 256, 100)))  # 4 frames

    def corrupt_seq1(tx, tx_valid):
        tx = np.array(tx)
        for r in range(tx.shape[0]):
            for t in range(tx.shape[1]):
                if tx_valid[r, t] and (tx[r, t, HDR_ROUTE] & 0xFFFF) == 1:
                    tx[r, t, HDR_ROUTE + 2] ^= 0x40
        return tx

    fab.tx_hook = corrupt_seq1
    fab.send(0, 4, wire)
    kept = []
    for _ in range(40):
        fab.exchange()
        kept.extend(fab.drain(4, on_corrupt="retry"))
        if kept and kept[-1].ok:
            break
    assert [d.ok for d in kept] == [True], kept
    assert kept[0].wire == wire
    ctr = _counters(fab)
    assert ctr["fabric.arq.replays"] == 1
    assert ctr["fabric.arq.aborts"] >= 1
    assert ctr["fabric.arq.skips"] >= 1


def test_stream_reader_on_corrupt_modes():
    from repro.obs import MetricsRegistry
    from repro.stream import StreamReader, TokenChunk, encode_chunk_burst

    class D:  # a fabric Delivery stand-in
        def __init__(self, wire, ok):
            self.src, self.wire, self.ok, self.list_level = 1, wire, ok, 1
            self.arrive_step = 0

    clean = encode_chunk_burst([TokenChunk(7, 0, (1, 2), False)])
    dirty = encode_chunk_burst([TokenChunk(7, 1, (3,), True)])

    r = StreamReader()  # flag: the stream is poisoned but tokens kept
    r.feed([D(clean, True), D(dirty, False)])
    st = r.streams[(1, 7)]
    assert not st.ok and st.tokens == [1, 2, 3]

    r = StreamReader(on_corrupt="raise")
    r.feed([D(clean, True)])
    with pytest.raises(RuntimeError, match="corrupt stream delivery"):
        r.feed([D(dirty, False)])

    m = MetricsRegistry()
    r = StreamReader(metrics=m, on_corrupt="retry")
    r.feed([D(clean, True), D(dirty, False)])
    st = r.streams[(1, 7)]
    assert st.ok and st.tokens == [1, 2]  # damage skipped, stream healthy
    assert r.feed([D(dirty, True)])  # the clean replay repairs the stream
    assert r.streams[(1, 7)].eos
    snap = {x["name"]: x["value"] for x in m.snapshot()["metrics"]
            if x["type"] == "counter"}
    assert snap["stream.reader.skipped_corrupt"] == 1
    with pytest.raises(ValueError, match="flag"):
        StreamReader(on_corrupt="bogus")


# ---------------------------------------------------------------------------
# chaos plan plumbing
# ---------------------------------------------------------------------------


def test_parse_chaos():
    p = parse_chaos("drop=0.02,corrupt=0.01,blackout_rank=2,"
                    "blackout_from=3,blackout_ticks=10", seed=7)
    assert (p.seed, p.drop, p.corrupt) == (7, 0.02, 0.01)
    assert (p.blackout_rank, p.blackout_from, p.blackout_ticks) == (2, 3, 10)
    assert p.active
    with pytest.raises(ValueError):
        parse_chaos("warp_speed=1")
    assert not FaultPlan(seed=0).active
    assert FaultPlan(seed=0).with_seed(5).seed == 5


def test_fault_plan_is_deterministic_per_seed(rng):
    """Same seed = same fault decisions; different seed = (almost surely)
    different ones.  The plan is stateless, so planning twice from the
    same inputs must agree — that is what engine parity rests on."""
    plan = FaultPlan(seed=13, drop=0.3, duplicate=0.3)
    frames = [(0, 4, s, 0) for s in range(64)]  # (src, dst, seq, fidx)
    a = plan.frame_ops(2, frames, dup_budget=8)
    b = plan.frame_ops(2, frames, dup_budget=8)
    assert a == b
    c = plan.with_seed(14).frame_ops(2, frames, dup_budget=8)
    assert a != c


# ---------------------------------------------------------------------------
# static rules: the knob set must be provably coherent
# ---------------------------------------------------------------------------


def test_arq_rules_fire():
    from repro.analysis.rules import arq_config_findings

    # seq-window ambiguity is an ERROR at construction, message shared
    # verbatim with the analyzer
    with pytest.raises(ValueError, match="seq window"):
        _cfg(arq_buffer=SEQ_MOD // 2)
    with pytest.raises(ValueError, match="retransmit_timeout"):
        _cfg(retransmit_timeout=0)
    # control-class starvation: class 255 % 2 = 1 earns floor(4*1/9) = 0
    with pytest.raises(ValueError, match="control class"):
        _cfg(credits=4, qos_weights=(8, 1))
    with pytest.raises(ValueError, match="skip past a gap"):
        _cfg(retransmit_timeout=8, arq_skip_after=8)
    # suspect_after is serve-side, so it is analyzer-only
    fs = arq_config_findings(retransmit_timeout=8, max_retries=4,
                             suspect_after=8)
    assert any(f.rule == "fabric-arq-timeout" for f in fs)
    assert arq_config_findings(retransmit_timeout=8, max_retries=4,
                               suspect_after=24) == []


def test_arq_targets_in_strict_sweep():
    """The shipped --strict sweep must actually exercise the ARQ rules:
    the serve fabric and the faulty-link bench are analyzed with their
    real arq knobs."""
    from repro.analysis.targets import fabric_targets

    arq_targets = [kw for _, kw in fabric_targets() if kw.get("arq")]
    assert len(arq_targets) >= 2
    assert any("suspect_after" in kw for kw in arq_targets)


# ---------------------------------------------------------------------------
# max_retransmit_ratio SLO
# ---------------------------------------------------------------------------


def _snap(**counters):
    return {"schema": 1, "metrics": [
        {"name": k, "type": "counter", "labels": {}, "value": v}
        for k, v in counters.items()]}


def test_max_retransmit_ratio_slo():
    from repro.obs import evaluate_slo

    ok = evaluate_slo("max_retransmit_ratio=0.1", _snap(**{
        "fabric.arq.retransmits": 4, "fabric.frames.delivered": 100}))
    assert ok.ok and ok.results[0].observed == pytest.approx(0.04)
    bad = evaluate_slo("max_retransmit_ratio=0.01", _snap(**{
        "fabric.arq.retransmits": 4, "fabric.frames.delivered": 100}))
    assert not bad.ok and bad.results[0].burn_rate == pytest.approx(4.0)
    # absent signal must FAIL, not silently pass
    absent = evaluate_slo("max_retransmit_ratio=0.1", _snap())
    assert not absent.ok and "absent" in absent.results[0].detail
    # generic bounds still work next to it (regression for the dispatch)
    both = evaluate_slo(
        "max_retransmit_ratio=0.1,max:fabric.arq.aborts=0",
        _snap(**{"fabric.arq.retransmits": 0, "fabric.frames.delivered": 10,
                 "fabric.arq.aborts": 0}))
    assert both.ok and len(both.results) == 2


# ---------------------------------------------------------------------------
# failure-aware serving (blackout completes; chaos stays byte-identical)
# ---------------------------------------------------------------------------


@pytest.fixture(scope="module")
def serve_setup():
    import jax

    from repro.configs import get_config, smoke_config
    from repro.launch.serve import encode_request, serve_requests
    from repro.models import init_params

    cfg = dataclasses.replace(smoke_config(get_config("yi-6b")), n_layers=2)
    params = init_params(cfg, jax.random.PRNGKey(0))
    r = np.random.default_rng(0)
    wires = [encode_request(i, [list(map(int, r.integers(2, cfg.vocab, 12)))
                                for _ in range(2)])
             for i in range(4)]
    kw = dict(max_new=4, pad_to=8, slots=4)
    base = serve_requests(params, cfg, wires, **kw)
    return params, cfg, wires, kw, base


def test_streaming_chaos_byte_identical(serve_setup):
    from repro.launch.serve import default_serve_fabric, serve_requests_streaming

    params, cfg, wires, kw, base = serve_setup
    fab = default_serve_fabric(
        3, faults=FaultPlan(seed=7, drop=0.05, corrupt=0.02))
    got = serve_requests_streaming(params, cfg, wires, fabric=fab, **kw)
    assert got == base
    ctr = _counters(fab)
    assert ctr["fabric.arq.aborts"] == 0


def test_sharded_blackout_completes(serve_setup):
    from repro.launch.serve import default_serve_fabric, serve_requests_sharded

    params, cfg, wires, kw, base = serve_setup
    # from=1 kills shard 2's RESPONSE leg (the sharded round trip is only
    # ~2 ticks, so a later blackout would miss the exchange entirely)
    plan = FaultPlan(seed=7, blackout_rank=2, blackout_from=1,
                     blackout_ticks=1 << 20)
    fab = default_serve_fabric(3, faults=plan)
    got = serve_requests_sharded(params, cfg, wires, fabric=fab,
                                 placement=[1, 2, 3, 2], suspect_after=8,
                                 **kw)
    assert got == base
    ctr = _counters(fab, prefix="serve.")
    assert ctr["serve.suspects"] >= 1 and ctr["serve.retries"] >= 1


def test_streaming_blackout_completes_with_retry_spans(serve_setup):
    from repro.launch.serve import default_serve_fabric, serve_requests_streaming
    from repro.obs import SpanTracker

    params, cfg, wires, kw, base = serve_setup
    plan = FaultPlan(seed=7, blackout_rank=2, blackout_from=2,
                     blackout_ticks=1 << 20)
    fab = default_serve_fabric(3, faults=plan)
    spans = SpanTracker()
    got = serve_requests_streaming(params, cfg, wires, fabric=fab,
                                   spans=spans, placement=[1, 2, 3, 2],
                                   suspect_after=8, **kw)
    assert got == base
    retried = [s.rid for s in spans.requests()
               if any(e.name == "serve.retry" for e in s.events)]
    assert retried, "blackout recovery must leave serve.retry span events"


def test_suspect_exhaustion_raises(serve_setup):
    """When EVERY shard is dead (100% frame loss) the serve must fail
    loudly — retry-once exhausted or no healthy shard left to place on —
    instead of hanging until the heat death of the deadline."""
    from repro.launch.serve import default_serve_fabric, serve_requests_sharded

    params, cfg, wires, kw, base = serve_setup
    fab = default_serve_fabric(2, faults=FaultPlan(seed=0, drop=1.0))
    with pytest.raises((RuntimeError, ValueError)):
        serve_requests_sharded(params, cfg, wires, fabric=fab,
                               placement=[1, 1, 2, 2], suspect_after=8,
                               deadline_ticks=64, **kw)
