"""Pallas kernels vs pure-jnp oracles: shape/dtype sweeps (interpret mode)."""
import numpy as np
import jax.numpy as jnp
import pytest

from repro.kernels import (
    decode_gather, decode_message_kernel, decode_run, encode_run,
    wire_to_u32, write_headers,
)
from repro.kernels import ref
from repro.kernels.ops import runs_from_plan


@pytest.mark.parametrize("nbytes", [1, 2, 3, 4, 7, 8, 12, 16])
@pytest.mark.parametrize("base,stride_kind", [
    (0, "tight"), (4, "tight"), (5, "padded"), (13, "word"), (0, "word"),
])
def test_unpack_run_vs_oracle(rng, nbytes, base, stride_kind):
    stride = {
        "tight": nbytes, "padded": nbytes + 1, "word": ((nbytes + 3) // 4) * 4
    }[stride_kind]
    stride = max(stride, nbytes)
    for count in (1, 5, 300):
        wirelen = base + stride * count + 16
        w32 = wire_to_u32(rng.integers(0, 256, wirelen, dtype=np.uint8).tobytes())
        got = decode_run(w32, base, stride, count, nbytes)
        want = ref.unpack_run_ref(w32, base, stride, count, nbytes)
        np.testing.assert_array_equal(np.asarray(got), np.asarray(want))


@pytest.mark.parametrize("nbytes", [1, 3, 4, 8, 16])
@pytest.mark.parametrize("n", [1, 17, 256, 513])
def test_unpack_gather_vs_oracle(rng, nbytes, n):
    offs = np.sort(rng.choice(8000, size=n, replace=False)).astype(np.int32)
    w32 = wire_to_u32(rng.integers(0, 256, 8192 + 32, dtype=np.uint8).tobytes())
    got = decode_gather(w32, jnp.asarray(offs), nbytes)
    want = ref.unpack_gather_ref(w32, jnp.asarray(offs), nbytes)
    np.testing.assert_array_equal(np.asarray(got), np.asarray(want))


@pytest.mark.parametrize("nbytes", [1, 4, 8, 13, 16])
@pytest.mark.parametrize("n", [1, 256, 517])
def test_pack_run_vs_oracle(rng, nbytes, n):
    nlanes = (nbytes + 3) // 4
    for stride in (nlanes * 4, nlanes * 4 + 4, 32):
        toks = jnp.asarray(rng.integers(0, 2**32, (n, nlanes), dtype=np.uint32))
        got = encode_run(toks, stride, nbytes)
        want = ref.pack_run_ref(toks, stride, nbytes)
        np.testing.assert_array_equal(np.asarray(got), np.asarray(want))


def test_pack_unpack_roundtrip(rng):
    toks = jnp.asarray(rng.integers(0, 2**32, (300, 4), dtype=np.uint32))
    wire = encode_run(toks, 16, 16)
    back = decode_run(wire, 0, 16, 300, 16)
    np.testing.assert_array_equal(np.asarray(back), np.asarray(toks))


def test_stamp_headers(rng):
    w32 = wire_to_u32(rng.integers(0, 256, 4096, dtype=np.uint8).tobytes())
    hdr = np.array([[0, 100, 1], [128, 0, 2], [512, 64, 1], [1000, 4, 3]], np.int32)
    got = write_headers(w32, jnp.asarray(hdr))
    want = ref.stamp_headers_ref(w32, hdr)
    np.testing.assert_array_equal(np.asarray(got), np.asarray(want))


def test_decode_message_kernel_end_to_end(rng):
    from repro.core import (Schema, build_plan, lanes_to_int, random_message,
                            ser_sw_to_hw)
    schema = Schema.from_json({
        "Msg": [["hdr", ["Bytes", 8]],
                 ["a", ["List", ["Array", ["Struct", "T"]]]],
                 ["tail", ["Bytes", 2]]],
        "T": [["x", ["Bytes", 4]], ["y", ["Bytes", 8]]],
    })
    for i in range(10):
        msg = random_message(schema, np.random.default_rng(i), max_elems=6)
        wire = ser_sw_to_hw(schema, msg)
        plan = build_plan(schema, msg)
        dec = decode_message_kernel(wire_to_u32(wire), plan)
        xs = [e["x"] for arr in msg["a"] for e in arr]
        ys = [e["y"] for arr in msg["a"] for e in arr]
        got_x = lanes_to_int(np.asarray(dec["a.elem.elem.x"]), 4)[: len(xs)]
        got_y = lanes_to_int(np.asarray(dec["a.elem.elem.y"]), 8)[: len(ys)]
        assert list(got_x) == xs and list(got_y) == ys
        assert lanes_to_int(np.asarray(dec["hdr"]), 8)[0] == msg["hdr"]


def test_runs_from_plan_detects_uniform(rng):
    from repro.core import Schema, build_plan, random_message
    schema = Schema.from_json({"M": [["a", ["Array", ["Bytes", 16]]]]})
    msg = {"a": [1, 2, 3, 4]}
    plan = build_plan(schema, msg)
    assert runs_from_plan(plan, "a.elem") == (4, 16)
