"""Schema tree / ROM compilation (paper §IV-A2)."""

from repro.core import (
    ClientSchema, Schema, build_rom, build_tree, tree_depth,
    KIND_ARRAY, KIND_BYTES, KIND_END, KIND_LIST,
)

PAPER_SCHEMA = {
    "Msg": [["a", ["List", ["Array", ["Struct", "Tuple"]]]], ["b", ["Bytes", 1]]],
    "Tuple": [["x", ["Bytes", 4]], ["y", ["Bytes", 8]]],
}


def test_tree_matches_paper_fig11():
    s = Schema.from_json(PAPER_SCHEMA)
    roots = build_tree(s)
    # root children: a (List), b (Bytes), END
    assert [n.kind for n in roots] == [KIND_LIST, KIND_BYTES, KIND_END]
    a = roots[0]
    assert len(a.children) == 1 and a.children[0].kind == KIND_ARRAY
    xy = a.children[0].children
    assert [n.kind for n in xy] == [KIND_BYTES, KIND_BYTES]
    assert [n.nbytes for n in xy] == [4, 8]
    assert tree_depth(roots) == 2


def test_struct_inlining():
    s = Schema.from_json({
        "M": [["p", ["Struct", "Inner"]], ["q", ["Bytes", 2]]],
        "Inner": [["u", ["Bytes", 1]], ["v", ["Bytes", 1]]],
    })
    roots = build_tree(s)
    # Inner's fields are inlined: u, v, q, END all siblings
    assert [n.path for n in roots] == ["p.u", "p.v", "q", ""]


def test_rom_layout_siblings_consecutive():
    s = Schema.from_json(PAPER_SCHEMA)
    rom = build_rom(s)
    # entry 0 = a (List), 1 = b, 2 = END, then a's child (Array), then x,y
    assert list(rom.kind[:3]) == [KIND_LIST, KIND_BYTES, KIND_END]
    child = int(rom.child[0])
    assert rom.kind[child] == KIND_ARRAY
    gc = int(rom.child[child])
    assert list(rom.kind[gc : gc + 2]) == [KIND_BYTES, KIND_BYTES]
    assert rom.last[gc + 1] == 1  # y is last child
    assert rom.stack_depth == 2


def test_rom_tags_and_emit_end():
    s = Schema.from_json(PAPER_SCHEMA)
    cs = ClientSchema.from_json({
        "a.start": 1, "a.elem.start": 2, "a.elem.elem.x": 3,
        "a.elem.elem.y": 4, "a.elem.end": 5, "a.end": 6, "b": 7,
    })
    rom = build_rom(s, cs)
    arr = int(rom.child[0])
    assert rom.emit_end[arr] == 1  # array-end tagged -> emitted
    assert rom.tag_end[arr] == 5
    # untag the array end -> not emitted (paper §III-C1)
    cs2 = ClientSchema.from_json({"a.elem.elem.x": 3})
    rom2 = build_rom(s, cs2)
    assert rom2.emit_end[int(rom2.child[0])] == 0
    assert rom2.emit_end[0] == 1  # lists ALWAYS emit list-end


def test_list_level_annotation():
    s = Schema.from_json({
        "M": [["a", ["List", ["List", ["Bytes", 4]]]], ["d", ["Bytes", 4]]],
    })
    rom = build_rom(s)
    assert rom.list_level[0] == 1  # outer list
    inner = int(rom.child[0])
    assert rom.list_level[inner] == 2
