"""HGum data plane: bulk SER byte-identity, device decode, prefetch."""
import numpy as np
import pytest

from repro.core import plan_from_wire, ser_sw_to_hw
from repro.data import HGumBatchPipeline, Prefetcher, SyntheticCorpus, pack_documents
from repro.data.pipeline import batch_plan, decode_batch, serialize_batch
from repro.data.schemas import batch_schema


def test_bulk_ser_byte_identical_to_reference(rng):
    corpus = SyntheticCorpus(512, seed=3)
    tokens, segids = pack_documents(corpus.docs(), 4, 32)
    wire = serialize_batch(tokens, segids)
    schema = batch_schema(32)
    msg = {"rows": [
        {"tokens": list(map(int, tokens[b])), "segids": list(map(int, segids[b]))}
        for b in range(4)
    ]}
    assert wire == ser_sw_to_hw(schema, msg)


def test_static_plan_matches_wire_plan():
    corpus = SyntheticCorpus(512, seed=5)
    tokens, segids = pack_documents(corpus.docs(), 3, 16)
    wire = serialize_batch(tokens, segids)
    p1 = batch_plan(3, 16)
    p2 = plan_from_wire(batch_schema(16), wire)
    for k in p2.offsets:
        n = p2.counts[k]
        assert p1.counts[k] == n
        np.testing.assert_array_equal(p1.offsets[k][:n], p2.offsets[k][:n])


def test_decode_batch_roundtrip():
    corpus = SyntheticCorpus(512, seed=7)
    tokens, segids = pack_documents(corpus.docs(), 4, 32)
    wire = serialize_batch(tokens, segids)
    batch = decode_batch(wire, 4, 32)
    np.testing.assert_array_equal(np.asarray(batch["tokens"]), tokens.astype(np.int32))
    np.testing.assert_array_equal(
        np.asarray(batch["segment_ids"]), segids.astype(np.int32)
    )
    # labels are next-token within segment; mask zero at segment boundaries
    lm = np.asarray(batch["loss_mask"])
    toks = np.asarray(batch["tokens"])
    labels = np.asarray(batch["labels"])
    segs = np.asarray(batch["segment_ids"])
    B, S = toks.shape
    for b in range(B):
        for s in range(S - 1):
            if lm[b, s]:
                assert labels[b, s] == toks[b, s + 1]
                assert segs[b, s] == segs[b, s + 1]
    assert lm[:, -1].sum() == 0  # last position never scored


def test_positions_restart_per_segment():
    corpus = SyntheticCorpus(512, seed=11)
    tokens, segids = pack_documents(corpus.docs(), 2, 64)
    wire = serialize_batch(tokens, segids)
    batch = decode_batch(wire, 2, 64)
    segs = np.asarray(batch["segment_ids"])
    pos = np.asarray(batch["positions"])
    for b in range(2):
        for s in range(1, 64):
            if segs[b, s] != segs[b, s - 1]:
                assert pos[b, s] == 0, (b, s)
            else:
                assert pos[b, s] == pos[b, s - 1] + 1


def test_pipeline_iterates():
    pipe = HGumBatchPipeline(vocab=256, batch=2, seq=32, seed=0)
    b1, b2 = next(pipe), next(pipe)
    assert b1["tokens"].shape == (2, 32)
    assert not np.array_equal(np.asarray(b1["tokens"]), np.asarray(b2["tokens"]))


def test_prefetcher_orders_and_closes():
    import itertools
    c = itertools.count()
    pf = Prefetcher(lambda: next(c), depth=3)
    vals = [pf.get() for _ in range(8)]
    pf.close()
    assert vals == sorted(vals)


def test_prefetcher_surfaces_errors():
    def boom():
        raise RuntimeError("producer died")
    pf = Prefetcher(boom, depth=1)
    import time
    time.sleep(0.2)
    with pytest.raises(RuntimeError):
        pf.get(timeout=2)
    pf.close()


def test_straggler_watchdog():
    import time as _t
    from repro.data.prefetch import StragglerWatchdog
    dog = StragglerWatchdog(threshold=3.0)
    for i in range(10):
        dog.start(); _t.sleep(0.002); assert dog.stop() is False
    dog.start(); _t.sleep(0.05)
    assert dog.stop() is True
    assert dog.flagged == 1
