"""Streaming message plane: chunk codec, writer/reader reassembly, QoS
credit classes, async overlap, topology-aware placement, and token-identity
of the streamed serve path.

Runs on the 8 simulated host devices from ``conftest.py`` (the CI
multi-device job re-runs this file explicitly)."""
import dataclasses

import jax
import numpy as np
import pytest

from repro.fabric import Delivery, Fabric, FabricConfig
from repro.stream import (
    ChunkLane,
    StreamReader,
    TokenChunk,
    decode_token_chunks,
    encode_chunk_burst,
    encode_token_chunk,
)


# ---------------------------------------------------------------------------
# chunk wire format
# ---------------------------------------------------------------------------


def test_chunk_roundtrip_and_burst_identity(rng):
    """The batched Pallas burst is bit-identical to concatenated single
    chunks, and the back-to-front parse (count after elements, §IV-B)
    recovers every chunk in emission order."""
    chunks = [
        TokenChunk(int(rng.integers(0, 1 << 20)), s,
                   tuple(map(int, rng.integers(0, 1 << 31, int(n)))),
                   eos=bool(e))
        for s, (n, e) in enumerate([(3, 0), (0, 0), (1, 0), (13, 1), (7, 1)])
    ]
    burst = encode_chunk_burst(chunks)
    ref = b"".join(
        encode_token_chunk(c.stream_id, c.step, c.tokens, c.eos)
        for c in chunks
    )
    assert burst == ref
    got, ok = decode_token_chunks(burst)
    assert ok and got == chunks
    # empty burst and single empty-token EOS chunk
    assert encode_chunk_burst([]) == b""
    eos = encode_token_chunk(7, 4, (), eos=True)
    got, ok = decode_token_chunks(eos)
    assert ok and got == [TokenChunk(7, 4, (), eos=True)]


def test_chunk_parse_flags_malformed():
    wire = encode_token_chunk(1, 0, (2, 3))
    # truncated to a partial word: parser flags, salvages nothing extra
    got, ok = decode_token_chunks(wire[:-2])
    assert not ok
    # impossible trailing count: flagged, but earlier chunks still salvage
    two = encode_token_chunk(1, 0, (2, 3)) + encode_token_chunk(1, 1, (4,))
    bad = bytearray(two)
    bad[-4:] = (0xFFFFFFF0).to_bytes(4, "little")
    got, ok = decode_token_chunks(bytes(bad))
    assert not ok


# ---------------------------------------------------------------------------
# writer/reader over the fabric
# ---------------------------------------------------------------------------


@pytest.fixture
def fab():
    """Tiny frames force multi-frame chunk bursts through the router."""
    return Fabric(n_ranks=8, config=FabricConfig(frame_phits=1, credits=2))


def test_stream_writer_reader_over_fabric(fab, rng):
    """Two shards stream interleaved multi-chunk token streams to rank 0;
    the reader reassembles each exactly, in step order, and sees EOS."""
    lanes = {s: ChunkLane(fab.mailbox(s), 0) for s in (2, 5)}
    writers = {
        (s, sid): lanes[s].writer(sid) for s in (2, 5) for sid in (10, 11)
    }
    sent = {k: [] for k in writers}
    reader = StreamReader()
    lens = {(2, 10): 5, (2, 11): 2, (5, 10): 4, (5, 11): 1}
    for step in range(5):
        for (s, sid), w in writers.items():
            if step < lens[(s, sid)]:
                toks = list(map(int, rng.integers(0, 1 << 31, 2)))
                sent[(s, sid)].extend(toks)
                w.write(toks, eos=(step == lens[(s, sid)] - 1))
        for lane in lanes.values():
            lane.flush()
        fab.exchange()
        for ev in reader.feed(fab.mailbox(0).recv()):
            assert ev.ok
    assert reader.all_eos(sent.keys())
    for k, toks in sent.items():
        assert reader.streams[k].tokens == toks and reader.streams[k].ok


def test_stream_corruption_flags_exactly_one_stream(fab):
    """A frame corrupted in transit poisons the stream whose chunks rode in
    that burst — other tenants' streams stay clean."""
    lane_a = ChunkLane(fab.mailbox(1), 0, list_level=1)
    lane_b = ChunkLane(fab.mailbox(3), 0, list_level=2)
    wa, wb = lane_a.writer(1), lane_b.writer(2)
    wa.write((111, 112), eos=True)
    wb.write((221, 222), eos=True)
    lane_a.flush()
    lane_b.flush()

    def corrupt(tx, tx_valid):
        tx = np.array(tx)
        tx[1, 0, 5] ^= 0x4  # payload word of rank 1's first frame
        return tx

    fab.tx_hook = corrupt
    fab.exchange()
    fab.tx_hook = None
    reader = StreamReader()
    reader.feed(fab.mailbox(0).recv())
    assert not reader.streams[(1, 1)].ok
    assert reader.streams[(3, 2)].ok
    assert reader.streams[(3, 2)].tokens == [221, 222]


def test_stream_reader_arrive_stats():
    """The reader aggregates each chunk's router arrive step into the
    latency trace benchmarks read (mean / p95 / max / jitter)."""
    reader = StreamReader()
    assert reader.arrive_stats()["n"] == 0
    for step, arrive in enumerate((2, 2, 6, 2)):
        reader.feed([Delivery(1, encode_token_chunk(9, step, (step,)),
                              arrive_step=arrive)])
    st = reader.arrive_stats()
    assert st["n"] == 4 and st["max"] == 6.0 and st["mean"] == 3.0
    assert st["jitter"] > 0
    assert reader.streams[(1, 9)].arrive_steps == [2, 2, 6, 2]


def test_arrive_stats_p95_ceil_rank():
    """Satellite: p95 is nearest-rank with a CEIL rank — the smallest
    value with >= 95% of the trace at or below it.  The old floor index
    ``arr[int(0.95 * n)]`` was one rank high (at n=20 it reported the
    max).  Pinned for n in {1, 10, 20, 100} on arr = 1..n."""
    from repro.stream import arrive_stats

    for n, want in ((1, 1.0), (10, 10.0), (20, 19.0), (100, 95.0)):
        st = arrive_stats(range(1, n + 1))
        assert st["p95"] == want, (n, st["p95"])
        assert st["max"] == float(n)
    # order-independent: a shuffled trace reports the same percentile
    assert arrive_stats([5, 1, 4, 2, 3] * 4)["p95"] == 5.0
    assert arrive_stats([])["p95"] == 0.0


def test_missing_arrive_step_not_recorded_as_zero():
    """Satellite: a delivery that lacks ``arrive_step`` contributes NO
    latency sample — recording 0 would claim an impossible zero-step
    arrival, deflating mean/p95 and inflating jitter (the very signal the
    backpressure scheduler feeds on)."""
    class BareDelivery:  # a duck-typed delivery without the field
        def __init__(self, src, wire):
            self.src, self.wire = src, wire
            self.ok, self.list_level = True, 1

    reader = StreamReader()
    evs = reader.feed([BareDelivery(1, encode_token_chunk(9, 0, (7,)))])
    assert evs[0].arrive_step is None  # surfaced as unknown, not 0
    assert reader.streams[(1, 9)].arrive_steps == []
    assert reader.arrive_stats()["n"] == 0
    # mixing observed deliveries in: only the observed ones count
    reader.feed([Delivery(1, encode_token_chunk(9, 1, (8,)), arrive_step=4)])
    reader.feed([BareDelivery(1, encode_token_chunk(9, 2, (9,)))])
    st = reader.arrive_stats()
    assert st["n"] == 1 and st["mean"] == 4.0 and st["jitter"] == 0.0
    assert reader.streams[(1, 9)].tokens == [7, 8, 9]
    assert reader.streams[(1, 9)].ok  # missing latency is not corruption


def test_stream_reader_flags_step_gap():
    """A lost chunk (step gap) or a chunk after EOS marks the stream
    corrupt even when every frame CRC passes."""
    reader = StreamReader()
    reader.feed([Delivery(1, encode_token_chunk(9, 0, (1,)))])
    reader.feed([Delivery(1, encode_token_chunk(9, 2, (3,)))])  # step 1 lost
    assert not reader.streams[(1, 9)].ok
    reader2 = StreamReader()
    reader2.feed([Delivery(1, encode_token_chunk(9, 0, (1,), eos=True))])
    assert reader2.streams[(1, 9)].ok
    reader2.feed([Delivery(1, encode_token_chunk(9, 1, (2,)))])  # post-EOS
    assert not reader2.streams[(1, 9)].ok


# ---------------------------------------------------------------------------
# QoS credit classes
# ---------------------------------------------------------------------------


def test_qos_quotas_sum_and_floor():
    from repro.fabric.router import qos_quotas

    assert qos_quotas(4, (3, 1)) == (3, 1)
    assert qos_quotas(8, (1, 1)) == (4, 4)
    for credits, weights in ((4, (5, 1, 1, 1)), (5, (9, 1)), (7, (2, 3))):
        q = qos_quotas(credits, weights)
        assert sum(q) == credits and all(x >= 1 for x in q)
    with pytest.raises(ValueError):  # fewer credits than classes
        FabricConfig(credits=1, qos_weights=(1, 1))
    with pytest.raises(ValueError):
        FabricConfig(qos_weights=(0, 1))


def _tenant_arrival(qos_weights):
    """Saturating tenant (level 2) + light tenant (level 1) share the
    1 -> 0 multi-hop path; returns (light arrive step, heavy last step)."""
    fab = Fabric(
        n_ranks=4,
        config=FabricConfig(frame_phits=2, credits=4, qos_weights=qos_weights),
    )
    for i in range(8):
        fab.mailbox(1).send(0, bytes([i]) * 96, list_level=2)
    fab.mailbox(1).send(0, b"light-tenant", list_level=1)  # queued LAST
    fab.exchange()
    got = fab.mailbox(0).recv()
    assert all(d.ok for d in got) and len(got) == 9
    light = next(d for d in got if d.list_level == 1)
    assert light.wire == b"light-tenant"
    heavy_last = max(d.arrive_step for d in got if d.list_level == 2)
    return light.arrive_step, heavy_last


def test_qos_credit_classes_prevent_starvation():
    """FIFO credits drain the saturating tenant first — the light tenant's
    stream arrives last.  Weighted round-robin classes bound its wait."""
    fifo_light, fifo_heavy = _tenant_arrival(None)
    wrr_light, wrr_heavy = _tenant_arrival((3, 1))
    assert fifo_light >= fifo_heavy  # starved behind the whole burst
    assert wrr_light < fifo_light  # strictly earlier under WRR
    assert wrr_light < wrr_heavy  # no longer behind the saturating tenant
    # the link capacity is unchanged: the heavy burst finishes when it did
    assert wrr_heavy <= fifo_heavy + 1


def test_qos_classes_deliver_bit_exact(rng):
    """Mixed-class traffic under WRR arrives complete and uncorrupted."""
    fab = Fabric(
        n_ranks=8,
        config=FabricConfig(frame_phits=2, credits=4, qos_weights=(2, 1, 1)),
    )
    msgs = {}
    for s in range(8):
        for d in range(8):
            w = rng.integers(0, 256, int(rng.integers(1, 64)),
                             dtype=np.uint8).tobytes()
            msgs[(s, d)] = w
            fab.mailbox(s).send(d, w, list_level=int(rng.integers(1, 5)))
    fab.exchange()
    for d in range(8):
        got = fab.mailbox(d).recv()
        assert len(got) == 8
        for dl in got:
            assert dl.ok and dl.wire == msgs[(dl.src, d)]


# ---------------------------------------------------------------------------
# backpressure-fed lane scheduling
# ---------------------------------------------------------------------------


def test_lane_clamp_trickles_and_recovers(fab):
    """A clamped lane trickles its oldest chunk per flush and holds the
    rest; releasing the clamp flushes the backlog; tokens reassemble
    identically to an unclamped run."""
    lane = ChunkLane(fab.mailbox(1), 0, list_level=2, p95_threshold=3.0)
    w = lane.writer(5)
    w.write((1,))
    w.write((2,))
    w.write((3,))
    assert not lane.clamped
    lane.feedback(5.0)  # reader-side p95 above threshold -> clamp
    assert lane.clamped
    assert lane.flush() == 1 and lane.holds == 1  # oldest chunk trickles
    assert lane.flush() == 1 and lane.holds == 2
    lane.feedback(2.0)  # congestion drained -> release
    assert not lane.clamped
    w.write((4,), eos=True)
    assert lane.flush() == 2  # backlog + fresh chunk ride together
    fab.exchange()
    reader = StreamReader()
    reader.feed(fab.mailbox(0).recv())
    st = reader.streams[(1, 5)]
    assert st.ok and st.eos and st.tokens == [1, 2, 3, 4]


def test_lane_full_hold_bounded_by_max_hold(fab):
    """clamp_chunks=0 holds entirely; max_hold bounds consecutive holds so
    a stream can never stall forever; force=True bypasses the clamp."""
    lane = ChunkLane(fab.mailbox(2), 0, p95_threshold=1.0, clamp_chunks=0,
                     max_hold=2)
    w = lane.writer(9)
    lane.feedback(9.0)
    for i in range(2):
        w.write((i,))
        assert lane.flush() == 0  # held
    assert lane.holds == 2
    w.write((2,))
    assert lane.flush() == 3  # max_hold reached: accumulated burst goes out
    w.write((3,), eos=True)
    assert lane.flush(force=True) == 1  # force bypasses the active clamp
    fab.exchange()
    reader = StreamReader()
    reader.feed(fab.mailbox(0).recv())
    assert reader.streams[(2, 9)].tokens == [0, 1, 2, 3]
    assert reader.streams[(2, 9)].ok and reader.streams[(2, 9)].eos


def test_lane_feedback_none_never_clamps(fab):
    """No observation (None) and no threshold both mean: never clamp."""
    lane = ChunkLane(fab.mailbox(1), 0, p95_threshold=4.0)
    lane.feedback(None)
    assert not lane.clamped
    unthresholded = ChunkLane(fab.mailbox(1), 0)
    unthresholded.feedback(99.0)
    assert not unthresholded.clamped


def test_class_arrive_stats_reader_and_mailbox(fab):
    """Both ends of the feedback loop surface per-class percentiles: the
    StreamReader per ListLevel, the Fabric/Mailbox per scheduler class."""
    lane_hot = ChunkLane(fab.mailbox(1), 0, list_level=2)
    lane_cool = ChunkLane(fab.mailbox(3), 0, list_level=1)
    lane_hot.writer(1).write((11,), eos=True)
    lane_cool.writer(2).write((22,), eos=True)
    lane_hot.flush()
    lane_cool.flush()
    fab.exchange()
    got = fab.mailbox(0).recv()
    reader = StreamReader()
    reader.feed(got)
    per_level = reader.class_arrive_stats()
    assert set(per_level) == {1, 2}
    assert all(s["n"] >= 1 and s["p95"] >= 1 for s in per_level.values())
    # windowed view restricts to each stream's most recent samples
    assert reader.class_arrive_stats(window=1)[1]["n"] == 1
    # mailbox side: fab has no qos_weights -> single class 0 aggregates
    # both tenants' deliveries (class = level % n_classes)
    per_class = fab.mailbox(0).arrive_stats()
    assert set(per_class) == {0}
    assert per_class[0]["n"] == 2
    assert per_class[0]["max"] == max(s["max"] for s in per_level.values())


# ---------------------------------------------------------------------------
# async overlap
# ---------------------------------------------------------------------------


def test_exchange_async_double_buffer(fab):
    """Ticks dispatched back-to-back deliver in order; poll() reaps the
    in-flight tick; exchange() completes everything outstanding."""
    a, b = fab.mailbox(0), fab.mailbox(4)
    a.send(4, b"tick-1")
    assert fab.exchange_async()
    a.send(4, b"tick-2")
    assert fab.exchange_async()  # completes tick-1 first (depth-1 buffer)
    assert fab.poll()
    assert [d.wire for d in b.recv()] == [b"tick-1", b"tick-2"]
    assert not fab.poll()  # nothing in flight
    assert not fab.exchange_async()  # nothing pending
    a.send(4, b"tick-3")
    fab.exchange()  # sync path on top of the async plumbing
    assert [d.wire for d in b.recv()] == [b"tick-3"]


# ---------------------------------------------------------------------------
# topology-aware placement
# ---------------------------------------------------------------------------


def test_place_requests_nearest_free_shard():
    from repro.launch.serve import place_requests

    mesh = jax.make_mesh((4, 2), ("fx", "fy"))
    fab2 = Fabric(mesh=mesh, config=FabricConfig(frame_phits=2))
    r = fab2.router
    shards = list(range(1, 8))
    # x-major (4, 2) mesh: rank 1 is one y-hop away round-trip 2; rank 7 is
    # the far corner
    dist = {s: r.hops(0, s) + r.hops(s, 0) for s in shards}
    nearest = min(shards, key=lambda s: (dist[s], s))
    got = place_requests(r, 5, shards, capacity=2)
    assert got[0] == got[1] == nearest  # fills the nearest shard first
    assert dist[got[2]] <= dist[got[4]]  # spills outward by distance
    assert all(got.count(s) <= 2 for s in shards)
    # all-full overflow: least-loaded nearest takes the extras
    got = place_requests(r, 9, [1, 2], capacity=2)
    assert got.count(1) == 5 and got.count(2) == 4


# ---------------------------------------------------------------------------
# streamed sharded serve: token identity + streaming order
# ---------------------------------------------------------------------------


@pytest.fixture(scope="module")
def serve_setup():
    from repro.configs import get_config, smoke_config
    from repro.launch.serve import encode_request
    from repro.models import init_params

    cfg = dataclasses.replace(smoke_config(get_config("yi-6b")), n_layers=2)
    params = init_params(cfg, jax.random.PRNGKey(0))
    rng = np.random.default_rng(0)
    wires = []
    for r in range(4):
        prompts = [
            list(map(int, rng.integers(2, cfg.vocab, int(rng.integers(8, 16)))))
            for _ in range(int(rng.integers(1, 3)))
        ]
        wires.append(encode_request(r, prompts))
    return params, cfg, wires


def test_streaming_serve_token_identical(serve_setup):
    """Streamed final wires are byte-identical to the local batched plane,
    and tokens surface at the ingress in decode order per sequence."""
    from repro.launch.serve import serve_requests, serve_requests_streaming

    params, cfg, wires = serve_setup
    batched = serve_requests(params, cfg, wires, max_new=4, pad_to=8, slots=4)
    events = []
    streamed = serve_requests_streaming(
        params, cfg, wires, max_new=4, pad_to=8, slots=4, n_shards=3,
        on_token=lambda m, j, step, tok: events.append((m, j, step, tok)),
    )
    assert streamed == batched  # byte-identical response wires
    per_seq = {}
    for m, j, step, tok in events:
        assert step == len(per_seq.setdefault((m, j), []))  # in order
        per_seq[(m, j)].append(tok)
    assert all(len(t) == 4 for t in per_seq.values())


def test_streaming_overlap_identical(serve_setup):
    """The double-buffered async pipeline changes timing, not tokens."""
    from repro.launch.serve import serve_requests_streaming

    params, cfg, wires = serve_setup
    kw = dict(max_new=3, pad_to=8, slots=4, n_shards=2)
    a = serve_requests_streaming(params, cfg, wires, overlap=True, **kw)
    b = serve_requests_streaming(params, cfg, wires, overlap=False, **kw)
    assert a == b


def test_streaming_serve_backpressure_and_defection_token_identical(
    serve_setup,
):
    """Closing the backpressure loop (even absurdly tight: threshold 0
    clamps every lane from the first observation) and enabling direction
    defection delay bursts, never change tokens: the final wires stay
    byte-identical to the local batched plane."""
    from repro.launch.serve import serve_requests, serve_requests_streaming

    params, cfg, wires = serve_setup
    batched = serve_requests(params, cfg, wires, max_new=4, pad_to=8, slots=4)
    events = []
    streamed = serve_requests_streaming(
        params, cfg, wires, max_new=4, pad_to=8, slots=4, n_shards=3,
        qos_levels=[1 + (i % 2) for i in range(len(wires))],
        defect_after=1, backpressure_p95=0.0,
        on_event=events.append,
    )
    assert streamed == batched
    assert events and all(ev.arrive_step is not None for ev in events)


def test_streaming_multi_hop_qos_tenants(serve_setup):
    """Streams from a >= 2-hop shard under per-tenant QoS levels still
    reassemble token-identically."""
    from repro.launch.serve import serve_requests, serve_requests_streaming
    from repro.fabric import Fabric, FabricConfig

    params, cfg, wires = serve_setup
    fabric = Fabric(
        n_ranks=4,
        config=FabricConfig(frame_phits=16, credits=4, qos_weights=(3, 1)),
    )
    batched = serve_requests(params, cfg, wires, max_new=3, pad_to=8, slots=4)
    streamed = serve_requests_streaming(
        params, cfg, wires, max_new=3, pad_to=8, slots=4, fabric=fabric,
        placement=[3] * len(wires),  # 3 hops out, 1 hop back: >= 2-hop path
        qos_levels=[1 + (i % 2) for i in range(len(wires))],
    )
    assert streamed == batched


# ---------------------------------------------------------------------------
# property test: reassembly under random interleaving + corruption
# ---------------------------------------------------------------------------


def test_stream_reassembly_property():
    hypothesis = pytest.importorskip("hypothesis")
    from hypothesis import given, settings, strategies as st

    @st.composite
    def scenario(draw):
        n_src = draw(st.integers(1, 3))
        streams = {}
        bursts = {}  # src -> ordered burst wires
        for src in range(n_src):
            n_streams = draw(st.integers(1, 3))
            per_step = []
            for sid in range(n_streams):
                toks = draw(
                    st.lists(st.integers(0, 2**32 - 1), min_size=1, max_size=6)
                )
                streams[(src, sid)] = toks
            n_ticks = max(len(t) for t in streams.values()
                          if t is not None) if n_streams else 0
            burst_list = []
            for step in range(n_ticks):
                chunk_tick = []
                for sid in range(n_streams):
                    toks = streams[(src, sid)]
                    if step < len(toks):
                        chunk_tick.append(
                            TokenChunk(sid, step, (toks[step],),
                                       eos=(step == len(toks) - 1))
                        )
                if chunk_tick:
                    burst_list.append(encode_chunk_burst(chunk_tick))
            bursts[src] = burst_list
        # corrupt one delivery's token payload in some scenarios (CRC catch
        # is modelled by ok=False; the wire keeps parseable structure)
        corrupt = draw(st.booleans())
        victim = None
        if corrupt:
            src = draw(st.integers(0, n_src - 1))
            tick = draw(st.integers(0, len(bursts[src]) - 1))
            victim = (src, tick)
        order = draw(st.permutations(
            [(s, t) for s in bursts for t in range(len(bursts[s]))]
        ))
        # fabric guarantee: per-src FIFO — stable-sort the permutation by
        # tick within each src, keeping the cross-src interleaving random
        seen = {s: 0 for s in bursts}
        fifo = []
        for s, _ in order:
            fifo.append((s, seen[s]))
            seen[s] += 1
        return streams, bursts, fifo, victim

    @settings(max_examples=30, deadline=None)
    @given(scenario())
    def check(sc):
        streams, bursts, order, victim = sc
        reader = StreamReader()
        for src, tick in order:
            reader.feed([
                Delivery(src, bursts[src][tick], ok=(src, tick) != victim)
            ])
        poisoned = set()
        if victim is not None:
            src, tick = victim
            chunks, _ = decode_token_chunks(bursts[src][tick])
            poisoned = {(src, c.stream_id) for c in chunks}
        for key, toks in streams.items():
            st_ = reader.streams[key]
            if key in poisoned:
                assert not st_.ok  # corrupted stream is flagged
            else:  # surviving streams reconstruct exactly
                assert st_.ok and st_.tokens == toks and st_.eos

    check()
