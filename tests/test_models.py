"""Per-arch smoke + decode-consistency + scan-equivalence + grad-sanity."""
import dataclasses

import jax
import jax.numpy as jnp
import numpy as np
import pytest

from repro.configs import all_archs, get_config, smoke_config
from repro.models import (
    decode_step, forward, init_params, loss_fn,     plan_period, prefill,
)

KEY = jax.random.PRNGKey(0)


def make_batch(cfg, B, S, train=True, key=KEY):
    batch = {"tokens": jax.random.randint(key, (B, S), 0, cfg.vocab)}
    if train:
        batch["labels"] = batch["tokens"]
        batch["loss_mask"] = jnp.ones((B, S), jnp.float32)
    if cfg.family == "vlm":
        batch["vision"] = jax.random.normal(key, (B, cfg.vision_tokens, cfg.vision_dim))
    if cfg.family == "encdec":
        batch["audio"] = jax.random.normal(key, (B, cfg.enc_seq, cfg.d_model))
    return batch


@pytest.mark.parametrize("arch", all_archs())
def test_forward_shapes_no_nans(arch):
    cfg = smoke_config(get_config(arch))
    params = init_params(cfg, KEY)
    B, S = 2, 16
    batch = make_batch(cfg, B, S)
    logits, _, _ = forward(params, cfg, batch)
    assert logits.shape == (B, S, cfg.vocab)
    assert np.all(np.isfinite(np.asarray(logits, np.float32)))
    loss, metrics = loss_fn(params, cfg, batch)
    assert np.isfinite(float(loss))


@pytest.mark.parametrize("arch", all_archs())
def test_train_step_reduces_loss(arch):
    """A few AdamW steps on one small batch must reduce the loss.
    (AdamW, not raw SGD: the SSM families' exponential-gate parameters
    diverge under naive SGD at any useful step size.)"""
    from repro.optim import AdamWConfig, adamw_init, adamw_update
    cfg = smoke_config(get_config(arch))
    params = init_params(cfg, KEY)
    batch = make_batch(cfg, 2, 16)
    g_fn = jax.jit(jax.value_and_grad(lambda p: loss_fn(p, cfg, batch)[0]))
    opt_cfg = AdamWConfig(lr=3e-3, weight_decay=0.0)
    st = adamw_init(params)
    l0, _ = g_fn(params)
    for _ in range(8):
        l, g = g_fn(params)
        params, st, _ = adamw_update(g, st, params, opt_cfg, opt_cfg.lr)
    l1, _ = g_fn(params)
    assert float(l1) < float(l0), (float(l0), float(l1))


@pytest.mark.parametrize("arch", all_archs())
def test_decode_matches_forward(arch):
    cfg = dataclasses.replace(smoke_config(get_config(arch)), capacity_factor=16.0)
    params = init_params(cfg, KEY)
    B, S, P = 2, 12, 8
    batch = make_batch(cfg, B, S, train=False, key=jax.random.PRNGKey(1))
    full_logits, _, _ = forward(params, cfg, batch)
    b0 = dict(batch)
    b0["tokens"] = batch["tokens"][:, :P]
    logits_p, cache = prefill(params, cfg, b0, cache_len=S)
    errs = [np.abs(np.asarray(logits_p) - np.asarray(full_logits[:, :P])).max()]
    for t in range(P, S):
        lg, cache = decode_step(params, cfg, cache, batch["tokens"][:, t : t + 1])
        errs.append(np.abs(np.asarray(lg[:, 0]) - np.asarray(full_logits[:, t])).max())
    assert max(errs) < 2e-2, errs


@pytest.mark.parametrize("arch", ["gemma2-27b", "jamba-1.5-large-398b",
                                   "mixtral-8x22b", "xlstm-125m", "granite-34b"])
def test_scan_layers_equivalence(arch):
    cfg = smoke_config(get_config(arch))
    params = init_params(cfg, KEY)
    batch = make_batch(cfg, 2, 16, train=False)
    l0, _, _ = forward(params, cfg, batch)
    l1, _, _ = forward(params, dataclasses.replace(cfg, scan_layers=True), batch)
    np.testing.assert_allclose(
        np.asarray(l0, np.float32), np.asarray(l1, np.float32), atol=1e-4
    )


def test_plan_periods():
    assert plan_period(smoke_config(get_config("gemma2-27b"))) == 2
    assert plan_period(smoke_config(get_config("yi-6b"))) == 1
    assert plan_period(smoke_config(get_config("jamba-1.5-large-398b"))) == 8
    assert plan_period(smoke_config(get_config("xlstm-125m"))) == 2


def test_full_param_counts_match_published_class():
    """6ND bookkeeping: total params within 25% of the advertised size."""
    expect = {
        "gemma2-27b": 27e9, "granite-34b": 34e9, "yi-6b": 6e9,
        "mixtral-8x22b": 141e9, "jamba-1.5-large-398b": 398e9,
        "phi3.5-moe-42b-a6.6b": 42e9, "phi-3-vision-4.2b": 4.2e9,
        "xlstm-125m": 125e6,
    }
    for arch, want in expect.items():
        got = get_config(arch).param_counts()["total"]
        assert 0.7 < got / want < 1.35, (arch, got, want)


def test_moe_capacity_drops_and_balance():
    from repro.models.ffn import init_moe_ffn, moe_ffn
    cfg = dataclasses.replace(
        smoke_config(get_config("mixtral-8x22b")), capacity_factor=0.5
    )
    p = init_moe_ffn(KEY, cfg, jnp.float32)
    x = jax.random.normal(KEY, (2, 32, cfg.d_model))
    y, aux = moe_ffn(p, x, cfg)
    assert y.shape == x.shape
    assert float(aux["moe_dropped"]) > 0  # capacity 0.5 must drop
    cfg2 = dataclasses.replace(cfg, capacity_factor=8.0)
    y2, aux2 = moe_ffn(p, x, cfg2)
    assert float(aux2["moe_dropped"]) == 0.0
