"""Splice the generated roofline table into EXPERIMENTS.md and append the
hillclimb + multi-pod summaries from the tagged dryrun JSONs."""
import glob
import json
import subprocess
import sys

MARK = "<!-- ROOFLINE_TABLE -->"


def table(mesh="single", tag=""):
    out = subprocess.run(
        [sys.executable, "scripts/roofline_table.py", "--mesh", mesh, "--tag", tag],
        capture_output=True, text=True,
    )
    return out.stdout


def hillclimb_rows():
    rows = []
    for fn in sorted(glob.glob("experiments/dryrun/*_single_*.json")):
        r = json.load(open(fn))
        if r["status"] != "ok":
            rows.append((r["arch"], r["shape"], r["tag"], "FAILED", "", "", "", ""))
            continue
        rf = r["roofline"]
        rows.append((
            r["arch"], r["shape"], r["tag"],
            f"{rf['t_compute']*1e3:.1f}", f"{rf['t_memory']*1e3:.1f}",
            f"{rf['t_collective']*1e3:.1f}",
            f"{r['memory']['per_device_bytes']/2**30:.2f}",
            f"{rf['useful_ratio']:.2f}",
        ))
    return rows


def multi_rows():
    rows = []
    for fn in sorted(glob.glob("experiments/dryrun/*_multi.json")):
        r = json.load(open(fn))
        if r["status"] == "skipped":
            continue
        if r["status"] != "ok":
            rows.append(f"| {r['arch']} | {r['shape']} | FAILED | | {r.get('error','')[:60]} |")
            continue
        rf = r["roofline"]
        rows.append(
            f"| {r['arch']} | {r['shape']} | ok | "
            f"{r['memory']['per_device_bytes']/2**30:.2f} GiB | "
            f"t=({rf['t_compute']*1e3:.1f}, {rf['t_memory']*1e3:.1f}, "
            f"{rf['t_collective']*1e3:.1f}) ms dom={rf['dominant'][2:]} |"
        )
    return rows


def main():
    md = open("EXPERIMENTS.md").read()
    tbl = table("single")
    block = f"{MARK}\n\n{tbl}\n"
    if MARK in md:
        pre, _, post = md.partition(MARK)
        # drop any previously spliced table up to the next section header
        idx = post.find("\nTerms:")
        post = post[idx:] if idx >= 0 else post
        md = pre + block + post
    open("EXPERIMENTS.md", "w").write(md)

    # hillclimb + multi summaries to stdout (pasted manually into §Perf)
    print("== hillclimb variants ==")
    print("| arch | shape | tag | t_c ms | t_m ms | t_x ms | GiB | 6ND/HLO |")
    for r in hillclimb_rows():
        print("| " + " | ".join(str(x) for x in r) + " |")
    print("\n== multi-pod cells ==")
    for r in multi_rows():
        print(r)


if __name__ == "__main__":
    main()
