"""Render the roofline table (EXPERIMENTS.md §Roofline) from dryrun JSONs.

Usage: python scripts/roofline_table.py [--mesh single] [--md]
"""
import argparse
import glob
import json
import os

ARCH_ORDER = [
    "gemma2-27b", "granite-34b", "yi-6b", "stablelm-3b", "whisper-tiny",
    "jamba-1.5-large-398b", "mixtral-8x22b", "phi3.5-moe-42b-a6.6b",
    "phi-3-vision-4.2b", "xlstm-125m",
]
SHAPE_ORDER = ["train_4k", "prefill_32k", "decode_32k", "long_500k"]


def load(d="experiments/dryrun"):
    out = {}
    for fn in glob.glob(os.path.join(d, "*.json")):
        r = json.load(open(fn))
        out[(r["arch"], r["shape"], r["mesh"], r.get("tag", ""))] = r
    return out


def fmt_row(r):
    if r["status"] == "skipped":
        return None
    if r["status"] != "ok":
        return f"| {r['arch']} | {r['shape']} | FAILED | | | | | | | {r.get('error','')[:60]} |"
    rf = r["roofline"]
    m = r["memory"]
    t_c, t_m, t_x = rf["t_compute"], rf["t_memory"], rf["t_collective"]
    dom = rf["dominant"][2:]
    note = {
        "compute": "raise arithmetic intensity / cut redundant compute",
        "memory": "fuse attention (Pallas flash) / cut remat re-reads",
        "collective": "overlap or shrink collectives (EP/TP layout)",
    }[dom]
    return (
        f"| {r['arch']} | {r['shape']} | {t_c*1e3:9.2f} | {t_m*1e3:9.2f} | "
        f"{t_x*1e3:9.2f} | **{dom}** | {m['per_device_bytes']/2**30:5.2f} | "
        f"{'Y' if m['fits'] else 'N'} | {rf['useful_ratio']:.2f} | {note} |"
    )


def main():
    ap = argparse.ArgumentParser()
    ap.add_argument("--mesh", default="single")
    ap.add_argument("--tag", default="")
    args = ap.parse_args()
    rows = load()
    print("| arch | shape | t_comp (ms) | t_mem (ms) | t_coll (ms) | dominant "
          "| GiB/dev | fits | 6ND/HLO | to move the bottleneck |")
    print("|---|---|---|---|---|---|---|---|---|---|")
    n_ok = n_skip = n_fail = 0
    for a in ARCH_ORDER:
        for s in SHAPE_ORDER:
            r = rows.get((a, s, args.mesh, args.tag))
            if r is None:
                print(f"| {a} | {s} | (pending) | | | | | | | |")
                continue
            if r["status"] == "skipped":
                n_skip += 1
                print(f"| {a} | {s} | — | — | — | skipped | — | — | — | {r['reason'][:50]} |")
                continue
            line = fmt_row(r)
            if r["status"] == "ok":
                n_ok += 1
            else:
                n_fail += 1
            print(line)
    print(f"\nok={n_ok} skipped={n_skip} failed={n_fail}")


if __name__ == "__main__":
    main()
